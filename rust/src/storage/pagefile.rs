//! Tempfile-backed page files: the spill device under the paged tiers.
//!
//! A [`PageFile`] is an `rows × cols` f32 grid stored row-major in a real
//! temporary file, split into fixed-size row-band pages (`page_rows` rows
//! each; the last page may be short). Reads and writes move real bytes
//! through the filesystem *and* charge simulated I/O time through the
//! existing [`SimFs`] cost model — the spill device is a link with an
//! aggregate bandwidth, exactly like the shared feature filesystem, just
//! (by default) an NVMe-class faster one
//! ([`DEFAULT_SPILL_GBPS`](crate::storage::DEFAULT_SPILL_GBPS)).
//!
//! Values round-trip bit-exactly: f32s are stored as their little-endian
//! bit patterns, so a page read back after eviction is indistinguishable
//! from the page that was written — the foundation of the storage
//! determinism contract (eviction changes I/O counts, never values).

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::SimFs;
use crate::Result;

/// Process-wide uniquifier for spill-file names (many ranks and scopes
/// create files concurrently).
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

fn spill_path(tag: &str) -> PathBuf {
    let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = crate::storage::storage_dir().unwrap_or_else(std::env::temp_dir);
    dir.join(format!(
        "deal-spill-{}-{}-{}.bin",
        std::process::id(),
        seq,
        tag
    ))
}

/// A file-backed `rows × cols` f32 grid in fixed row-band pages. In the
/// default (ephemeral) mode the backing file is a per-process tempfile
/// deleted on drop; in *durable* mode ([`PageFile::create_durable`] /
/// [`PageFile::open_durable`]) the file lives at a caller-named path that
/// survives both drop and process death — the checkpoint tier of the
/// durable store is built on it.
pub struct PageFile {
    path: PathBuf,
    file: File,
    /// Total rows in the grid.
    pub rows: usize,
    /// Columns per row.
    pub cols: usize,
    /// Rows per page (last page may be short).
    pub page_rows: usize,
    fs: Arc<SimFs>,
    /// Durable files are never deleted on drop.
    durable: bool,
    /// Raw bytes written to / read from the backing file.
    pub bytes_written: u64,
    pub bytes_read: u64,
}

impl PageFile {
    /// Create a zero-filled `rows × cols` page file under the system temp
    /// directory. `tag` names the file for debuggability; `fs` is the
    /// simulated spill device the I/O time is charged to.
    pub fn create(
        tag: &str,
        rows: usize,
        cols: usize,
        page_rows: usize,
        fs: Arc<SimFs>,
    ) -> Result<PageFile> {
        anyhow::ensure!(page_rows >= 1, "page_rows must be >= 1");
        let path = spill_path(tag);
        if let Some(parent) = path.parent() {
            // a pinned storage.dir may not exist yet
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        // Pre-size so unwritten pages read back as zeros (set_len
        // zero-fills the extension).
        file.set_len((rows * cols * 4) as u64)?;
        Ok(PageFile {
            path,
            file,
            rows,
            cols,
            page_rows,
            fs,
            durable: false,
            bytes_written: 0,
            bytes_read: 0,
        })
    }

    /// Create (truncating any existing file) a zero-filled durable page
    /// file at `path`. Unlike [`PageFile::create`], the file survives
    /// drop — removal is the caller's (the durable store's) job.
    pub fn create_durable(
        path: &Path,
        rows: usize,
        cols: usize,
        page_rows: usize,
        fs: Arc<SimFs>,
    ) -> Result<PageFile> {
        anyhow::ensure!(page_rows >= 1, "page_rows must be >= 1");
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.set_len((rows * cols * 4) as u64)?;
        Ok(PageFile {
            path: path.to_path_buf(),
            file,
            rows,
            cols,
            page_rows,
            fs,
            durable: true,
            bytes_written: 0,
            bytes_read: 0,
        })
    }

    /// Open an existing durable page file. The row count comes from the
    /// file's length, which must be an exact multiple of the row stride.
    pub fn open_durable(
        path: &Path,
        cols: usize,
        page_rows: usize,
        fs: Arc<SimFs>,
    ) -> Result<PageFile> {
        anyhow::ensure!(page_rows >= 1, "page_rows must be >= 1");
        anyhow::ensure!(cols >= 1, "cols must be >= 1");
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        let len = file.metadata()?.len();
        let stride = (cols * 4) as u64;
        anyhow::ensure!(
            len % stride == 0,
            "page file {:?}: length {} is not a multiple of the {}-byte row stride",
            path,
            len,
            stride
        );
        Ok(PageFile {
            path: path.to_path_buf(),
            file,
            rows: (len / stride) as usize,
            cols,
            page_rows,
            fs,
            durable: true,
            bytes_written: 0,
            bytes_read: 0,
        })
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of pages ( ⌈rows / page_rows⌉; 0 for an empty grid).
    pub fn n_pages(&self) -> usize {
        self.rows.div_ceil(self.page_rows)
    }

    /// Row range `[lo, hi)` covered by page `p`.
    pub fn page_row_range(&self, p: usize) -> (usize, usize) {
        let lo = p * self.page_rows;
        (lo, (lo + self.page_rows).min(self.rows))
    }

    /// Elements in page `p` (short for the last page).
    pub fn page_len(&self, p: usize) -> usize {
        let (lo, hi) = self.page_row_range(p);
        (hi - lo) * self.cols
    }

    /// Bytes page `p` occupies on the spill device.
    pub fn page_nbytes(&self, p: usize) -> u64 {
        self.page_len(p) as u64 * 4
    }

    /// Total bytes of the full grid.
    pub fn nbytes(&self) -> u64 {
        (self.rows * self.cols * 4) as u64
    }

    /// Charge `bytes` of traffic to the spill device; returns the
    /// transfer's duration (`SimFs::charge`: the shared device backlog
    /// advances so concurrent files serialize, but no file is ever
    /// re-charged backlog another file already paid for).
    fn charge(&mut self, bytes: u64) -> f64 {
        self.fs.charge(bytes)
    }

    /// Read page `p` into `out` (clearing it first). Returns the
    /// simulated I/O seconds charged.
    pub fn read_page(&mut self, p: usize, out: &mut Vec<f32>) -> Result<f64> {
        let len = self.page_len(p);
        let bytes = len as u64 * 4;
        let mut buf = vec![0u8; len * 4];
        self.file
            .seek(SeekFrom::Start((p * self.page_rows * self.cols * 4) as u64))?;
        self.file.read_exact(&mut buf)?;
        out.clear();
        out.reserve(len);
        for c in buf.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        self.bytes_read += bytes;
        Ok(self.charge(bytes))
    }

    /// Write page `p` from `data` (must be exactly the page's length).
    /// Returns the simulated I/O seconds charged.
    pub fn write_page(&mut self, p: usize, data: &[f32]) -> Result<f64> {
        let len = self.page_len(p);
        anyhow::ensure!(
            data.len() == len,
            "page {} holds {} elements, got {}",
            p,
            len,
            data.len()
        );
        let bytes = len as u64 * 4;
        let mut buf = Vec::with_capacity(len * 4);
        for v in data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.file
            .seek(SeekFrom::Start((p * self.page_rows * self.cols * 4) as u64))?;
        self.file.write_all(&buf)?;
        self.bytes_written += bytes;
        Ok(self.charge(bytes))
    }

    /// Sync written data to the backing file (explicit durability point;
    /// the cache's `flush` writes dirty pages first, then calls this).
    /// `sync_data` — `File`'s `Write::flush` is a no-op.
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data()?;
        Ok(())
    }
}

impl std::fmt::Debug for PageFile {
    // manual impl: `SimFs` (a mutex'd timeline) carries no Debug
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PageFile {{ path: {:?}, rows: {}, cols: {}, page_rows: {} }}",
            self.path, self.rows, self.cols, self.page_rows
        )
    }
}

impl Drop for PageFile {
    fn drop(&mut self) {
        if !self.durable {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs() -> Arc<SimFs> {
        SimFs::new(crate::storage::DEFAULT_SPILL_GBPS)
    }

    #[test]
    fn page_geometry() {
        let f = PageFile::create("geom", 10, 3, 4, fs()).unwrap();
        assert_eq!(f.n_pages(), 3);
        assert_eq!(f.page_row_range(0), (0, 4));
        assert_eq!(f.page_row_range(2), (8, 10), "last page is short");
        assert_eq!(f.page_len(2), 6);
        assert_eq!(f.nbytes(), 120);
        let empty = PageFile::create("geom0", 0, 3, 4, fs()).unwrap();
        assert_eq!(empty.n_pages(), 0);
    }

    #[test]
    fn roundtrip_is_bit_exact_and_charges_io() {
        let mut f = PageFile::create("rt", 6, 2, 4, fs()).unwrap();
        // include sign-of-zero and subnormals: bit patterns must survive
        let page0 = vec![1.5, -0.0, f32::MIN_POSITIVE / 2.0, -3.25e-7, 0.0, 7.0, -1.0, 2.0];
        let io_w = f.write_page(0, &page0).unwrap();
        assert!(io_w > 0.0, "writes cost simulated time");
        let mut back = Vec::new();
        let io_r = f.read_page(0, &mut back).unwrap();
        assert!(io_r > 0.0);
        let a: Vec<u32> = page0.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "bit-exact round trip");
        // unwritten (short) last page reads back as zeros
        f.read_page(1, &mut back).unwrap();
        assert_eq!(back, vec![0.0; 4]);
        assert_eq!(f.bytes_written, 32);
        assert_eq!(f.bytes_read, 32 + 16);
        // wrong-size write is rejected
        assert!(f.write_page(1, &[0.0; 8]).is_err());
    }

    #[test]
    fn file_is_removed_on_drop() {
        let path = {
            let f = PageFile::create("drop", 2, 2, 2, fs()).unwrap();
            f.path.clone()
        };
        assert!(!path.exists());
    }

    #[test]
    fn durable_file_survives_drop_and_reopens_bit_exact() {
        let dir = std::env::temp_dir().join(format!("deal-pf-durable-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("grid.pages");
        let vals = vec![1.5f32, -0.0, 2.5e-8, -4.0, 0.0, 9.0];
        {
            let mut f = PageFile::create_durable(&path, 3, 2, 2, fs()).unwrap();
            f.write_page(0, &vals[..4]).unwrap();
            f.write_page(1, &vals[4..]).unwrap();
            f.sync().unwrap();
        }
        assert!(path.exists(), "durable files survive drop");
        let mut f = PageFile::open_durable(&path, 2, 2, fs()).unwrap();
        assert_eq!((f.rows, f.n_pages()), (3, 2), "rows recovered from file length");
        let mut back = Vec::new();
        f.read_page(0, &mut back).unwrap();
        let mut tail = Vec::new();
        f.read_page(1, &mut tail).unwrap();
        back.extend_from_slice(&tail);
        let a: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "bit-exact across process-lifetime boundary");
        // ragged length is rejected
        drop(f);
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(23)
            .unwrap();
        assert!(PageFile::open_durable(&path, 2, 2, fs()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_files_land_in_the_pinned_storage_dir() {
        let dir = std::env::temp_dir().join(format!("deal-pf-sd-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        crate::storage::with_storage_dir(dir.to_str().unwrap(), || {
            let f = PageFile::create("pinned", 2, 2, 2, fs()).unwrap();
            assert!(f.path().starts_with(&dir), "spill path {:?}", f.path());
        });
        crate::storage::with_storage_dir("", || {
            let f = PageFile::create("ephemeral", 2, 2, 2, fs()).unwrap();
            assert!(
                f.path().starts_with(std::env::temp_dir()),
                "empty pin falls back to the tempdir"
            );
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
}
