//! Out-of-core tiered storage: paged feature/activation and adjacency
//! tiers behind a budgeted page cache (DESIGN.md §Out-of-core-storage).
//!
//! The paper's headline regime is multi-billion-edge graphs where memory,
//! not compute, is the binding constraint (Fig. 3b); InferTurbo
//! (arXiv:2307.00228) and DGI (arXiv:2211.15082) both bound inference
//! memory by staging state on disk and restricting the per-layer working
//! set. This module gives the repo those knobs:
//!
//! - [`PageFile`] — a tempfile-backed grid of fixed-size row-band pages
//!   with explicit read/write/flush and simulated I/O time from the
//!   existing [`SimFs`](crate::coordinator::SimFs) cost model (the spill
//!   device is modeled like a link with an aggregate bandwidth).
//! - [`PageCache`] — a per-rank byte-budgeted cache of decoded pages with
//!   **deterministic logical-clock (LRU) eviction**: every access stamps a
//!   monotonically increasing tick and eviction always takes the
//!   minimum-stamp frame. LRU is a stack algorithm (inclusion property),
//!   so page-fault counts are monotone non-increasing as the budget grows
//!   — the property `tests/storage.rs` asserts. Eviction order can change
//!   *I/O counts only*: a faulted page is re-read bit-for-bit from the
//!   page file, so values never depend on what was cached.
//! - [`PagedMatrix`] / [`PagedCsr`] — the typed tiers: feature/activation
//!   rows and layer-graph adjacency bands.
//!
//! The byte budget follows the PR 3/4 knob-chain pattern:
//! [`with_mem_budget`] scope → [`set_mem_budget`] global
//! (`storage.budget_bytes` config / `--mem-budget` CLI) →
//! `DEAL_MEM_BUDGET` env → unbounded (`0`). Page granularity:
//! [`with_page_rows`] → [`set_page_rows`] (`storage.page_rows`) →
//! `DEAL_PAGE_ROWS` → [`DEFAULT_PAGE_ROWS`]. `Cluster::run` and
//! `Ctx::with_server` capture the caller's effective values, so a pinned
//! sweep reaches every simulated machine and its server thread. The
//! storage *directory* follows the same chain ([`with_storage_dir`] →
//! [`set_storage_dir`] / `storage.dir` / `--storage-dir` →
//! `DEAL_STORAGE_DIR` → ephemeral tempdir) and additionally roots the
//! [`durable`] log-structured store (DESIGN.md §Durability).
//!
//! **Determinism contract**: at every budget, page size, chunk size, and
//! thread count the computed values are bit-identical to the in-memory
//! path. The tiers only ever change *where bytes live* and *when
//! simulated time is charged*; every consumer reads rows in the same
//! order it would have read them from a resident matrix.

pub mod cache;
pub mod durable;
pub mod pagefile;
pub mod paged;

use std::cell::{Cell, RefCell};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::cluster::Ctx;

pub use cache::{FileId, PageCache, SharedPageCache};
pub use durable::{DurableOptions, DurableStore, EpochHistory, Recovered};
pub use pagefile::PageFile;
pub use paged::{PagedCsr, PagedMatrix};

/// Default rows per page for the paged tiers: 256 rows of a 128-wide f32
/// tile is 128 KiB per page — large enough to amortize per-page I/O,
/// small enough that a handful of pages fit tight budgets.
pub const DEFAULT_PAGE_ROWS: usize = 256;

/// Simulated aggregate bandwidth of the per-rank spill device in Gbps
/// (NVMe-class: 16 Gbps = 2 GB/s), fed to the [`SimFs`] cost model each
/// paged scope creates. The shared *feature* filesystem stays at the
/// EFS-like 4 Gbps the coordinator already uses.
pub const DEFAULT_SPILL_GBPS: f64 = 16.0;

/// Sentinel for "no override" in the knob chains (`0` is a meaningful
/// budget — unbounded — so unset needs its own marker).
const BUDGET_UNSET: u64 = u64::MAX;
const PAGE_ROWS_UNSET: usize = usize::MAX;

static GLOBAL_BUDGET: AtomicU64 = AtomicU64::new(BUDGET_UNSET);
static GLOBAL_PAGE_ROWS: AtomicUsize = AtomicUsize::new(PAGE_ROWS_UNSET);

thread_local! {
    static LOCAL_BUDGET: Cell<u64> = const { Cell::new(BUDGET_UNSET) };
    static LOCAL_PAGE_ROWS: Cell<usize> = const { Cell::new(PAGE_ROWS_UNSET) };
}

/// Set the process-global storage byte budget (`0` = unbounded). Wired to
/// `DealConfig.storage.budget_bytes` and the `--mem-budget` CLI flag;
/// `u64::MAX` resets to auto (env or unbounded).
pub fn set_mem_budget(bytes: u64) {
    GLOBAL_BUDGET.store(bytes, Ordering::Relaxed);
}

/// Run `f` with the storage budget pinned to `bytes` on this thread
/// (`0` = unbounded). `Cluster::run` and `Ctx::with_server` capture the
/// caller's effective value, so a pinned sweep reaches every simulated
/// machine — the storage parity tests rely on this.
pub fn with_mem_budget<T>(bytes: u64, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_BUDGET.with(|c| c.replace(bytes));
    let out = f();
    LOCAL_BUDGET.with(|c| c.set(prev));
    out
}

fn env_budget() -> u64 {
    static ENV: OnceLock<u64> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DEAL_MEM_BUDGET")
            .ok()
            .and_then(|v| parse_bytes(&v).ok())
            .unwrap_or(0)
    })
}

/// Effective storage byte budget for paged scopes opened on this thread:
/// [`with_mem_budget`] scope → [`set_mem_budget`] global (config/CLI) →
/// `DEAL_MEM_BUDGET` env → `0` (unbounded — the in-memory tiers). The
/// budget never changes results — only page-fault counts and simulated
/// I/O time (DESIGN.md §Out-of-core-storage).
pub fn mem_budget() -> u64 {
    let local = LOCAL_BUDGET.with(|c| c.get());
    if local != BUDGET_UNSET {
        return local;
    }
    let global = GLOBAL_BUDGET.load(Ordering::Relaxed);
    if global != BUDGET_UNSET {
        return global;
    }
    env_budget()
}

/// Set the process-global page granularity in rows (`usize::MAX` resets
/// to auto). Wired to `DealConfig.storage.page_rows`.
pub fn set_page_rows(n: usize) {
    GLOBAL_PAGE_ROWS.store(n, Ordering::Relaxed);
}

/// Run `f` with the page granularity pinned to `n` rows on this thread.
pub fn with_page_rows<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_PAGE_ROWS.with(|c| c.replace(n));
    let out = f();
    LOCAL_PAGE_ROWS.with(|c| c.set(prev));
    out
}

fn env_page_rows() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DEAL_PAGE_ROWS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_PAGE_ROWS)
    })
}

/// Effective rows-per-page for paged tiers created on this thread:
/// [`with_page_rows`] scope → [`set_page_rows`] global → `DEAL_PAGE_ROWS`
/// env → [`DEFAULT_PAGE_ROWS`]; clamped to at least 1. Page size never
/// changes results — only page counts and fault granularity.
pub fn page_rows() -> usize {
    let local = LOCAL_PAGE_ROWS.with(|c| c.get());
    if local != PAGE_ROWS_UNSET {
        return local.max(1);
    }
    let global = GLOBAL_PAGE_ROWS.load(Ordering::Relaxed);
    if global != PAGE_ROWS_UNSET {
        return global.max(1);
    }
    env_page_rows().max(1)
}

// ------------------------------------------------------- storage.dir knob

static GLOBAL_STORAGE_DIR: Mutex<Option<String>> = Mutex::new(None);

thread_local! {
    // tri-state: None = unset (fall through), Some("") = pinned ephemeral
    // (overrides global/env — tests use this to opt out of a CI-wide
    // DEAL_STORAGE_DIR), Some(dir) = pinned directory.
    static LOCAL_STORAGE_DIR: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Set the process-global storage directory (`storage.dir` config /
/// `--storage-dir` CLI). Empty string resets to auto (env or ephemeral).
pub fn set_storage_dir(dir: &str) {
    let mut g = GLOBAL_STORAGE_DIR.lock().expect("storage dir lock");
    *g = if dir.is_empty() {
        None
    } else {
        Some(dir.to_string())
    };
}

/// Run `f` with the storage directory pinned on this thread. An empty
/// string pins *ephemeral* mode (tempdir spills, no durable store) even
/// when a global or `DEAL_STORAGE_DIR` value is set — tests that must
/// not share an ambient directory rely on this.
pub fn with_storage_dir<T>(dir: &str, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_STORAGE_DIR.with(|c| c.replace(Some(dir.to_string())));
    let out = f();
    LOCAL_STORAGE_DIR.with(|c| *c.borrow_mut() = prev);
    out
}

fn env_storage_dir() -> Option<&'static str> {
    static ENV: OnceLock<Option<String>> = OnceLock::new();
    ENV.get_or_init(|| std::env::var("DEAL_STORAGE_DIR").ok().filter(|v| !v.is_empty()))
        .as_deref()
}

/// Effective durable-storage directory for this thread:
/// [`with_storage_dir`] scope → [`set_storage_dir`] global
/// (`storage.dir` / `--storage-dir`) → `DEAL_STORAGE_DIR` env → `None`
/// (ephemeral: spill files are per-process tempfiles and nothing
/// survives exit). `Some(dir)` roots both the durable store
/// (`<dir>/ckpt-*.{pages,meta}`, `<dir>/wal-*.log`) and spill files.
pub fn storage_dir() -> Option<PathBuf> {
    let local = LOCAL_STORAGE_DIR.with(|c| c.borrow().clone());
    if let Some(pin) = local {
        return if pin.is_empty() {
            None
        } else {
            Some(PathBuf::from(pin))
        };
    }
    {
        let g = GLOBAL_STORAGE_DIR.lock().expect("storage dir lock");
        if let Some(dir) = g.as_ref() {
            return Some(PathBuf::from(dir));
        }
    }
    env_storage_dir().map(PathBuf::from)
}

/// Parse a byte count with optional binary suffix: `4096`, `256k`,
/// `64m`, `2g` (also `kb`/`kib` spellings, case-insensitive). Used by the
/// `storage.budget_bytes` config key, the `--mem-budget` CLI flag, and
/// the `DEAL_MEM_BUDGET` env var.
pub fn parse_bytes(s: &str) -> crate::Result<u64> {
    let t = s.trim().to_ascii_lowercase();
    const SUFFIXES: &[(&str, u64)] = &[
        ("gib", 1 << 30),
        ("mib", 1 << 20),
        ("kib", 1 << 10),
        ("gb", 1 << 30),
        ("mb", 1 << 20),
        ("kb", 1 << 10),
        ("g", 1 << 30),
        ("m", 1 << 20),
        ("k", 1 << 10),
        ("b", 1),
    ];
    let (num, mult) = SUFFIXES
        .iter()
        .find_map(|&(suf, mult)| t.strip_suffix(suf).map(|n| (n.trim(), mult)))
        .unwrap_or((t.as_str(), 1));
    anyhow::ensure!(!num.is_empty(), "empty byte count '{}'", s);
    let n: u64 = num
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte count '{}'", s))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte count '{}' overflows u64", s))
}

// ---------------------------------------------------------- Ctx adapters

/// Drain a paged scope's pending simulated I/O time into `ctx`'s clock
/// and mirror the cache's resident-byte delta into the rank's
/// `MemTracker`. Call after a batch of storage operations on the
/// machine's main thread. Server threads never call this: they drain
/// their own I/O inline (the `*_shared` helpers return it) and advance
/// their own clock via `ServerCtx::advance`, but never touch the rank
/// tracker — the alloc/free ledger stays single-writer.
pub fn charge_main(ctx: &mut Ctx, cache: &SharedPageCache) {
    let io = cache.with(|c| {
        c.sync_mem(&mut ctx.mem);
        c.take_io_secs()
    });
    ctx.advance(io);
}

/// Close a paged scope: drop every cached frame (no write-back — scope
/// files are dead), free the tracked resident bytes, and absorb the
/// scope's storage counters into the machine's metrics. The cache can be
/// reused for another scope afterwards.
pub fn absorb_scope(ctx: &mut Ctx, cache: &SharedPageCache) {
    let (io, stats) = cache.with(|c| {
        c.drop_all_frames();
        c.sync_mem(&mut ctx.mem);
        let stats = c.take_stats();
        (c.take_io_secs(), stats)
    });
    ctx.advance(io);
    ctx.metrics.storage.add(&stats);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("256k").unwrap(), 256 << 10);
        assert_eq!(parse_bytes("64m").unwrap(), 64 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2 << 30);
        assert_eq!(parse_bytes("64MB").unwrap(), 64 << 20);
        assert_eq!(parse_bytes("64MiB").unwrap(), 64 << 20);
        assert_eq!(parse_bytes(" 8 k ").unwrap(), 8 << 10);
        assert_eq!(parse_bytes("123b").unwrap(), 123);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("m").is_err());
        assert!(parse_bytes("1.5g").is_err());
        assert!(parse_bytes("99999999999999999999g").is_err());
    }

    #[test]
    fn budget_chain_resolution_order() {
        with_mem_budget(1234, || {
            assert_eq!(mem_budget(), 1234);
            with_mem_budget(0, || assert_eq!(mem_budget(), 0, "0 = unbounded, still a value"));
            assert_eq!(mem_budget(), 1234);
        });
        // outside any scope: global/env/default — just resolvable
        let _ = mem_budget();
    }

    #[test]
    fn page_rows_chain_clamps_to_one() {
        with_page_rows(7, || assert_eq!(page_rows(), 7));
        with_page_rows(0, || assert_eq!(page_rows(), 1, "granularity clamps to >= 1"));
        assert!(page_rows() >= 1);
    }

    #[test]
    fn storage_dir_chain_pins_and_overrides() {
        with_storage_dir("/tmp/deal-sd-test", || {
            assert_eq!(storage_dir(), Some(PathBuf::from("/tmp/deal-sd-test")));
            // nested empty pin = ephemeral, even under an outer pin
            with_storage_dir("", || assert_eq!(storage_dir(), None));
            assert_eq!(storage_dir(), Some(PathBuf::from("/tmp/deal-sd-test")));
        });
        // outside any pin: global/env/ephemeral — just resolvable
        let _ = storage_dir();
    }
}
