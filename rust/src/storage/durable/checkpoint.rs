//! Checkpoints: page-written table snapshots with an atomic commit.
//!
//! A checkpoint is two files per generation: `ckpt-<gen>.pages` — the raw
//! row-major f32 grid, written page-by-page through a *durable*
//! [`PageFile`] (so checkpoint I/O is charged to the same simulated spill
//! device as every other storage tier) — and `ckpt-<gen>.meta`, the
//! **commit point**: a small, checksummed header binding the generation,
//! epoch, geometry, seed, and a whole-grid FNV digest of the pages file.
//! A generation is live iff its meta file exists and self-checksums; a
//! crash anywhere before the meta write leaves only ignorable debris,
//! and a crash *during* it leaves a meta that fails its own checksum and
//! is likewise ignored. Recovery therefore picks the newest generation
//! with a valid meta and verifies the pages digest (a valid commit over
//! rotten pages is real corruption and fails loudly).
//!
//! The pages digest is computed incrementally during the write — the
//! bytes hashed are exactly the bytes written, in order.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::SimFs;
use crate::storage::PageFile;
use crate::tensor::Matrix;
use crate::util::{fnv1a, fnv1a_extend, FNV_OFFSET};
use crate::Result;

use super::crash::{self, CrashPoint};

/// Checkpoint meta-file magic.
pub const CKPT_MAGIC: [u8; 8] = *b"DEALCKPT";
/// Checkpoint format version.
pub const CKPT_VERSION: u32 = 1;
/// Meta-file length: magic + version + gen + epoch + rows + cols +
/// page_rows + seed + pages digest + trailing self-checksum.
pub const META_LEN: usize = 8 + 4 + 8 + 8 + 8 + 4 + 4 + 8 + 8 + 8;

/// Path of generation `gen`'s meta (commit-point) file.
pub fn meta_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("ckpt-{}.meta", gen))
}

/// Path of generation `gen`'s pages file.
pub fn pages_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("ckpt-{}.pages", gen))
}

/// A committed checkpoint's decoded meta file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointMeta {
    /// Generation number (file-name echo).
    pub gen: u64,
    /// Serving epoch the snapshot captures.
    pub epoch: u64,
    /// Table rows.
    pub rows: u64,
    /// Table columns (embedding width).
    pub cols: u32,
    /// Page granularity the pages file was written with.
    pub page_rows: u32,
    /// Pipeline seed echoed for mismatch detection.
    pub seed: u64,
    /// FNV-1a over the pages file's f32 little-endian bytes, in order.
    pub pages_fnv: u64,
}

/// Write generation `gen`'s checkpoint of `table` at `epoch` and commit
/// it. Every page write is a [`CrashPoint::CheckpointWrite`]; the meta
/// write is *the* [`CrashPoint::CheckpointCommit`]. Returns (bytes
/// written, simulated I/O seconds).
pub fn write(
    dir: &Path,
    gen: u64,
    epoch: u64,
    table: &Matrix,
    seed: u64,
    fs: &Arc<SimFs>,
) -> Result<(u64, f64)> {
    std::fs::create_dir_all(dir)?;
    // clobber any debris from a previously crashed attempt at this gen
    let _ = std::fs::remove_file(meta_path(dir, gen));
    let page_rows = crate::storage::page_rows();
    let mut pf = PageFile::create_durable(
        &pages_path(dir, gen),
        table.rows,
        table.cols,
        page_rows,
        Arc::clone(fs),
    )?;
    let mut io = 0.0;
    let mut digest = FNV_OFFSET;
    for p in 0..pf.n_pages() {
        crash::step(CrashPoint::CheckpointWrite)?;
        let (lo, hi) = pf.page_row_range(p);
        let band = &table.data[lo * table.cols..hi * table.cols];
        io += pf.write_page(p, band)?;
        for v in band {
            digest = fnv1a_extend(digest, &v.to_le_bytes());
        }
    }
    pf.sync()?;
    let bytes = pf.bytes_written;

    crash::step(CrashPoint::CheckpointCommit)?;
    let mut meta = Vec::with_capacity(META_LEN);
    meta.extend_from_slice(&CKPT_MAGIC);
    meta.extend_from_slice(&CKPT_VERSION.to_le_bytes());
    meta.extend_from_slice(&gen.to_le_bytes());
    meta.extend_from_slice(&epoch.to_le_bytes());
    meta.extend_from_slice(&(table.rows as u64).to_le_bytes());
    meta.extend_from_slice(&(table.cols as u32).to_le_bytes());
    meta.extend_from_slice(&(page_rows as u32).to_le_bytes());
    meta.extend_from_slice(&seed.to_le_bytes());
    meta.extend_from_slice(&digest.to_le_bytes());
    meta.extend_from_slice(&fnv1a(&meta).to_le_bytes());
    let mut f = OpenOptions::new()
        .write(true)
        .create(true)
        .truncate(true)
        .open(meta_path(dir, gen))?;
    f.write_all(&meta)?;
    f.sync_data()?;
    Ok((bytes + meta.len() as u64, io + fs.charge(meta.len() as u64)))
}

/// Read and validate generation `gen`'s meta file. An unreadable or
/// checksum-failing meta means the commit never completed — callers
/// treat that generation as absent, not corrupt.
pub fn read_meta(dir: &Path, gen: u64) -> Result<CheckpointMeta> {
    let bytes = std::fs::read(meta_path(dir, gen))?;
    anyhow::ensure!(
        bytes.len() == META_LEN && bytes[..8] == CKPT_MAGIC,
        "checkpoint meta gen {}: wrong length or magic",
        gen
    );
    let stored = u64::from_le_bytes(bytes[META_LEN - 8..].try_into().unwrap());
    anyhow::ensure!(
        fnv1a(&bytes[..META_LEN - 8]) == stored,
        "checkpoint meta gen {}: checksum mismatch (incomplete commit)",
        gen
    );
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let version = u32_at(8);
    anyhow::ensure!(
        version == CKPT_VERSION,
        "checkpoint meta gen {}: version {} (this build reads {})",
        gen,
        version,
        CKPT_VERSION
    );
    let meta = CheckpointMeta {
        gen: u64_at(12),
        epoch: u64_at(20),
        rows: u64_at(28),
        cols: u32_at(36),
        page_rows: u32_at(40),
        seed: u64_at(44),
        pages_fnv: u64_at(52),
    };
    anyhow::ensure!(
        meta.gen == gen,
        "checkpoint meta gen {}: file claims gen {}",
        gen,
        meta.gen
    );
    Ok(meta)
}

/// Load generation `gen`'s table: read the pages back through a durable
/// [`PageFile`] and verify the whole-grid digest against the committed
/// meta. A digest mismatch *here* is corruption (the commit was valid),
/// so it fails hard. Returns (meta, table, simulated I/O seconds).
pub fn read(dir: &Path, gen: u64, fs: &Arc<SimFs>) -> Result<(CheckpointMeta, Matrix, f64)> {
    let meta = read_meta(dir, gen)?;
    let mut pf = PageFile::open_durable(
        &pages_path(dir, gen),
        meta.cols as usize,
        (meta.page_rows as usize).max(1),
        Arc::clone(fs),
    )?;
    anyhow::ensure!(
        pf.rows as u64 == meta.rows,
        "checkpoint gen {}: pages file holds {} rows, meta says {}",
        gen,
        pf.rows,
        meta.rows
    );
    let mut data = Vec::with_capacity(meta.rows as usize * meta.cols as usize);
    let mut buf = Vec::new();
    let mut io = 0.0;
    for p in 0..pf.n_pages() {
        io += pf.read_page(p, &mut buf)?;
        data.extend_from_slice(&buf);
    }
    let mut digest = FNV_OFFSET;
    for v in &data {
        digest = fnv1a_extend(digest, &v.to_le_bytes());
    }
    anyhow::ensure!(
        digest == meta.pages_fnv,
        "checkpoint gen {}: pages digest {:#018x} != committed {:#018x} (pages file corrupt)",
        gen,
        digest,
        meta.pages_fnv
    );
    Ok((meta, Matrix::from_vec(meta.rows as usize, meta.cols as usize, data), io))
}

/// Generations present in `dir` (by meta file name, committed or not),
/// newest first.
pub fn list_gens(dir: &Path) -> Result<Vec<u64>> {
    let mut gens = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(gens), // absent dir = no checkpoints
    };
    for entry in entries {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(g) = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".meta"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            gens.push(g);
        }
    }
    gens.sort_unstable_by(|a, b| b.cmp(a));
    Ok(gens)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("deal-ckpt-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_read_roundtrip_is_bit_exact() {
        let dir = tmp_dir("rt");
        let fs = SimFs::new(16.0);
        let table = Matrix::from_vec(5, 3, (0..15).map(|i| (i as f32) * -0.5).collect());
        let (bytes, io) = crate::storage::with_page_rows(2, || {
            write(&dir, 3, 7, &table, 0x5EED, &fs)
        })
        .unwrap();
        assert!(bytes >= table.nbytes() && io > 0.0);
        let (meta, back, _) = read(&dir, 3, &fs).unwrap();
        assert_eq!(
            (meta.gen, meta.epoch, meta.rows, meta.cols, meta.seed),
            (3, 7, 5, 3, 0x5EED)
        );
        let a: Vec<u32> = table.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(list_gens(&dir).unwrap(), vec![3]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_commit_is_absent_but_rotten_pages_are_corrupt() {
        let dir = tmp_dir("commit");
        let fs = SimFs::new(16.0);
        let table = Matrix::from_vec(4, 2, vec![1.0; 8]);
        write(&dir, 0, 1, &table, 9, &fs).unwrap();
        // truncated meta = crashed commit: not an error, just not live
        let mp = meta_path(&dir, 0);
        let full = std::fs::read(&mp).unwrap();
        std::fs::write(&mp, &full[..full.len() - 3]).unwrap();
        assert!(read_meta(&dir, 0).is_err());
        // restore the commit, then rot the pages: now it is corruption
        std::fs::write(&mp, &full).unwrap();
        read(&dir, 0, &fs).unwrap();
        let pp = pages_path(&dir, 0);
        let mut pages = std::fs::read(&pp).unwrap();
        pages[5] ^= 0x40;
        std::fs::write(&pp, &pages).unwrap();
        let err = read(&dir, 0, &fs).unwrap_err();
        assert!(format!("{:#}", err).contains("corrupt"), "{:#}", err);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_lists_no_generations() {
        let dir = tmp_dir("empty");
        assert!(list_gens(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
        assert!(list_gens(&dir).unwrap().is_empty(), "absent dir too");
    }
}
