//! The write-ahead log: checksummed, versioned, generation-numbered.
//!
//! One WAL file exists per checkpoint generation (`wal-<gen>.log`). The
//! file opens with a fixed 40-byte header binding it to its store
//! (magic, format version, embedding dim, generation, node count, seed),
//! then carries a sequence of self-delimiting records:
//!
//! ```text
//! [u32 body_len][u64 fnv1a(body)][body]
//! ```
//!
//! Two record kinds exist (u8 tag leading the body): `Delta` — a PR 2
//! [`UpdateBatch`] plus the patch it produced (updated row ids and their
//! new values), i.e. *physiological* logging: the batch is the logical
//! audit trail, the patch lets recovery rebuild the table without
//! re-running inference — and `Publish` — a full-table serving-epoch
//! publish, journaled *after* its checkpoint committed, carrying the
//! table digest recovery re-verifies.
//!
//! Every append is `sync_data`'d before it returns (the
//! journal-before-publish contract: a record that wasn't durably on disk
//! was never client-visible) and charges the simulated spill device.
//!
//! [`scan`] distinguishes the two ways a log can be damaged: a record
//! extending past end-of-file is a **torn tail** — the expected residue
//! of a crash mid-append — and is trimmed back to the last record
//! boundary, while a fully-present record whose checksum mismatches is
//! **corruption** and fails recovery with the record's byte offset.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::coordinator::SimFs;
use crate::graph::delta::UpdateBatch;
use crate::tensor::Matrix;
use crate::util::fnv1a;
use crate::Result;

use super::crash::{self, CrashPoint};

/// WAL file magic (8 bytes; last byte doubles as a format generation).
pub const WAL_MAGIC: [u8; 8] = *b"DEALWAL\x01";
/// WAL format version.
pub const WAL_VERSION: u32 = 1;
/// Fixed file-header bytes: magic + version + dim + gen + n_nodes + seed.
pub const WAL_HEADER_LEN: u64 = 8 + 4 + 4 + 8 + 8 + 8;
/// Per-record framing bytes: u32 body length + u64 body checksum.
pub const REC_HEADER_LEN: usize = 4 + 8;

/// Path of generation `gen`'s WAL file.
pub fn wal_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("wal-{}.log", gen))
}

/// A decoded WAL record.
#[derive(Debug, Clone)]
pub enum WalRecord {
    /// An incremental update: the batch (audit) and the patch it produced
    /// (recovery applies `values` to rows `rows` — no inference rerun).
    Delta {
        /// Serving epoch this delta produced.
        epoch: u64,
        /// The logical update, exactly as applied.
        batch: UpdateBatch,
        /// Row ids the delta path recomputed.
        rows: Vec<u32>,
        /// New values for those rows (`rows.len() × dim`).
        values: Matrix,
    },
    /// A full-table publish (epoch swap from a complete refresh). The
    /// table itself lives in the checkpoint committed just before this
    /// record; the digest lets recovery verify it.
    Publish {
        /// Serving epoch published.
        epoch: u64,
        /// FNV-1a digest of the published table (see `table_digest`).
        digest: u64,
        /// Table geometry at publish time.
        rows: u64,
        /// Embedding width at publish time.
        dim: u32,
    },
}

impl WalRecord {
    /// Serving epoch this record produced.
    pub fn epoch(&self) -> u64 {
        match self {
            WalRecord::Delta { epoch, .. } => *epoch,
            WalRecord::Publish { epoch, .. } => *epoch,
        }
    }

    fn encode(&self, dim: usize) -> Result<Vec<u8>> {
        let mut b = Vec::new();
        match self {
            WalRecord::Delta {
                epoch,
                batch,
                rows,
                values,
            } => {
                anyhow::ensure!(
                    values.cols == dim && values.rows == rows.len(),
                    "delta patch shape {}x{} does not match {} rows x dim {}",
                    values.rows,
                    values.cols,
                    rows.len(),
                    dim
                );
                b.push(1u8);
                b.extend_from_slice(&epoch.to_le_bytes());
                b.extend_from_slice(&(batch.add_edges.len() as u32).to_le_bytes());
                for &(s, d) in &batch.add_edges {
                    b.extend_from_slice(&s.to_le_bytes());
                    b.extend_from_slice(&d.to_le_bytes());
                }
                b.extend_from_slice(&(batch.remove_edges.len() as u32).to_le_bytes());
                for &(s, d) in &batch.remove_edges {
                    b.extend_from_slice(&s.to_le_bytes());
                    b.extend_from_slice(&d.to_le_bytes());
                }
                b.extend_from_slice(&(batch.feature_updates.len() as u32).to_le_bytes());
                for (id, row) in &batch.feature_updates {
                    b.extend_from_slice(&id.to_le_bytes());
                    b.extend_from_slice(&(row.len() as u32).to_le_bytes());
                    for v in row {
                        b.extend_from_slice(&v.to_le_bytes());
                    }
                }
                b.extend_from_slice(&(rows.len() as u32).to_le_bytes());
                for r in rows {
                    b.extend_from_slice(&r.to_le_bytes());
                }
                for v in &values.data {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            WalRecord::Publish {
                epoch,
                digest,
                rows,
                dim: d,
            } => {
                b.push(2u8);
                b.extend_from_slice(&epoch.to_le_bytes());
                b.extend_from_slice(&digest.to_le_bytes());
                b.extend_from_slice(&rows.to_le_bytes());
                b.extend_from_slice(&d.to_le_bytes());
            }
        }
        Ok(b)
    }

    fn decode(body: &[u8], dim: usize) -> Result<WalRecord> {
        let mut r = Reader { bytes: body, pos: 0 };
        let kind = r.u8()?;
        let rec = match kind {
            1 => {
                let epoch = r.u64()?;
                let mut batch = UpdateBatch::default();
                for _ in 0..r.u32()? {
                    batch.add_edges.push((r.u32()?, r.u32()?));
                }
                for _ in 0..r.u32()? {
                    batch.remove_edges.push((r.u32()?, r.u32()?));
                }
                for _ in 0..r.u32()? {
                    let id = r.u32()?;
                    let n = r.u32()? as usize;
                    let mut row = Vec::with_capacity(n);
                    for _ in 0..n {
                        row.push(r.f32()?);
                    }
                    batch.feature_updates.push((id, row));
                }
                let n_rows = r.u32()? as usize;
                let mut rows = Vec::with_capacity(n_rows);
                for _ in 0..n_rows {
                    rows.push(r.u32()?);
                }
                let mut data = Vec::with_capacity(n_rows * dim);
                for _ in 0..n_rows * dim {
                    data.push(r.f32()?);
                }
                WalRecord::Delta {
                    epoch,
                    batch,
                    rows,
                    values: Matrix::from_vec(n_rows, dim, data),
                }
            }
            2 => WalRecord::Publish {
                epoch: r.u64()?,
                digest: r.u64()?,
                rows: r.u64()?,
                dim: r.u32()?,
            },
            k => anyhow::bail!("wal record: unknown kind {}", k),
        };
        anyhow::ensure!(r.pos == body.len(), "wal record: {} trailing bytes", body.len() - r.pos);
        Ok(rec)
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        anyhow::ensure!(self.pos + n <= self.bytes.len(), "wal record truncated");
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
}

/// An open, appendable WAL file.
pub struct Wal {
    file: File,
    /// Path of the backing file.
    pub path: PathBuf,
    /// Checkpoint generation this log extends.
    pub gen: u64,
    /// Embedding width every `Delta` patch in this log carries.
    pub dim: usize,
    /// Node count of the table this log describes.
    pub n_nodes: u64,
    /// Pipeline seed echoed for mismatch detection on recovery.
    pub seed: u64,
    /// Records currently in the log (replayed + appended).
    pub records: u64,
    /// Bytes appended through this handle (records + header if created).
    pub bytes_appended: u64,
}

fn encode_header(gen: u64, n_nodes: u64, dim: usize, seed: u64) -> Vec<u8> {
    let mut h = Vec::with_capacity(WAL_HEADER_LEN as usize);
    h.extend_from_slice(&WAL_MAGIC);
    h.extend_from_slice(&WAL_VERSION.to_le_bytes());
    h.extend_from_slice(&(dim as u32).to_le_bytes());
    h.extend_from_slice(&gen.to_le_bytes());
    h.extend_from_slice(&n_nodes.to_le_bytes());
    h.extend_from_slice(&seed.to_le_bytes());
    h
}

impl Wal {
    /// Create (truncating) generation `gen`'s WAL and sync its header.
    pub fn create(dir: &Path, gen: u64, n_nodes: u64, dim: usize, seed: u64) -> Result<Wal> {
        std::fs::create_dir_all(dir)?;
        let path = wal_path(dir, gen);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        let header = encode_header(gen, n_nodes, dim, seed);
        file.write_all(&header)?;
        file.sync_data()?;
        Ok(Wal {
            file,
            path,
            gen,
            dim,
            n_nodes,
            seed,
            records: 0,
            bytes_appended: header.len() as u64,
        })
    }

    /// Reopen a scanned WAL for appending. `scan` must have run first (it
    /// trims any torn tail back to a record boundary).
    pub fn open_for_append(path: &Path, scan: &WalScan) -> Result<Wal> {
        let file = OpenOptions::new().read(true).append(true).open(path)?;
        Ok(Wal {
            file,
            path: path.to_path_buf(),
            gen: scan.gen,
            dim: scan.dim,
            n_nodes: scan.n_nodes,
            seed: scan.seed,
            records: scan.records.len() as u64,
            bytes_appended: 0,
        })
    }

    /// Append and fsync one record; returns (bytes written, simulated
    /// I/O seconds). This is a [`CrashPoint::WalAppend`] — when armed,
    /// half the framed record reaches the disk (a real torn write) and
    /// the append fails.
    pub fn append(&mut self, rec: &WalRecord, fs: &SimFs) -> Result<(u64, f64)> {
        let body = rec.encode(self.dim)?;
        let mut buf = Vec::with_capacity(REC_HEADER_LEN + body.len());
        buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
        buf.extend_from_slice(&fnv1a(&body).to_le_bytes());
        buf.extend_from_slice(&body);
        if let Err(e) = crash::step(CrashPoint::WalAppend) {
            self.file.write_all(&buf[..buf.len() / 2])?;
            self.file.sync_data()?;
            return Err(e);
        }
        self.file.write_all(&buf)?;
        self.file.sync_data()?;
        self.records += 1;
        self.bytes_appended += buf.len() as u64;
        Ok((buf.len() as u64, fs.charge(buf.len() as u64)))
    }
}

/// Result of scanning (and, when needed, tail-trimming) a WAL file.
pub struct WalScan {
    /// Generation from the file header.
    pub gen: u64,
    /// Node count from the file header.
    pub n_nodes: u64,
    /// Embedding width from the file header.
    pub dim: usize,
    /// Seed echo from the file header.
    pub seed: u64,
    /// Every intact record, in append order.
    pub records: Vec<WalRecord>,
    /// Byte offset a torn tail was truncated at, if one was found.
    pub trimmed_at: Option<u64>,
    /// Valid bytes (post-trim), i.e. the scan's read volume.
    pub bytes: u64,
}

/// Scan a WAL file: validate the header, checksum every record, trim a
/// torn tail in place (crash residue — expected, not fatal), and fail
/// with the offending record's byte offset on checksum corruption.
pub fn scan(path: &Path) -> Result<WalScan> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(
        bytes.len() as u64 >= WAL_HEADER_LEN && bytes[..8] == WAL_MAGIC,
        "wal {:?}: missing or foreign header",
        path
    );
    let mut r = Reader {
        bytes: &bytes,
        pos: 8,
    };
    let version = r.u32()?;
    anyhow::ensure!(
        version == WAL_VERSION,
        "wal {:?}: version {} (this build reads {})",
        path,
        version,
        WAL_VERSION
    );
    let dim = r.u32()? as usize;
    let gen = r.u64()?;
    let n_nodes = r.u64()?;
    let seed = r.u64()?;

    let mut records = Vec::new();
    let mut trimmed_at = None;
    let mut pos = WAL_HEADER_LEN as usize;
    while pos < bytes.len() {
        if pos + REC_HEADER_LEN > bytes.len() {
            trimmed_at = Some(pos as u64);
            break;
        }
        let len = u32::from_le_bytes([bytes[pos], bytes[pos + 1], bytes[pos + 2], bytes[pos + 3]])
            as usize;
        let stored = u64::from_le_bytes([
            bytes[pos + 4],
            bytes[pos + 5],
            bytes[pos + 6],
            bytes[pos + 7],
            bytes[pos + 8],
            bytes[pos + 9],
            bytes[pos + 10],
            bytes[pos + 11],
        ]);
        let body_start = pos + REC_HEADER_LEN;
        if body_start + len > bytes.len() {
            // the record never fully reached the disk: torn tail
            trimmed_at = Some(pos as u64);
            break;
        }
        let body = &bytes[body_start..body_start + len];
        let actual = fnv1a(body);
        anyhow::ensure!(
            actual == stored,
            "wal {:?}: corrupt record at offset {} (stored checksum {:#018x}, computed {:#018x})",
            path,
            pos,
            stored,
            actual
        );
        records.push(
            WalRecord::decode(body, dim)
                .map_err(|e| e.context(format!("wal {:?}: record at offset {}", path, pos)))?,
        );
        pos = body_start + len;
    }
    if let Some(at) = trimmed_at {
        // trim so future appends extend from a record boundary
        OpenOptions::new().write(true).open(path)?.set_len(at)?;
    }
    Ok(WalScan {
        gen,
        n_nodes,
        dim,
        seed,
        records,
        trimmed_at,
        bytes: trimmed_at.unwrap_or(bytes.len() as u64),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("deal-wal-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_delta(epoch: u64) -> WalRecord {
        let mut batch = UpdateBatch::default();
        batch.add_edges.push((1, 2));
        batch.remove_edges.push((3, 4));
        batch.feature_updates.push((5, vec![0.5, -0.25]));
        WalRecord::Delta {
            epoch,
            batch,
            rows: vec![2, 5],
            values: Matrix::from_vec(2, 3, vec![1.0, -0.0, 2.5e-8, 4.0, 5.0, -6.0]),
        }
    }

    #[test]
    fn records_roundtrip_bit_exact() {
        let dir = tmp_dir("rt");
        let fs = SimFs::new(16.0);
        let mut wal = Wal::create(&dir, 0, 100, 3, 0xABC).unwrap();
        let (b1, io1) = wal.append(&sample_delta(1), &fs).unwrap();
        assert!(b1 > 0 && io1 > 0.0, "appends cost bytes and simulated time");
        wal.append(
            &WalRecord::Publish {
                epoch: 2,
                digest: 0xDEAD,
                rows: 100,
                dim: 3,
            },
            &fs,
        )
        .unwrap();
        drop(wal);
        let scan = scan(&wal_path(&dir, 0)).unwrap();
        assert_eq!((scan.gen, scan.n_nodes, scan.dim, scan.seed), (0, 100, 3, 0xABC));
        assert_eq!(scan.records.len(), 2);
        assert!(scan.trimmed_at.is_none());
        match &scan.records[0] {
            WalRecord::Delta {
                epoch,
                batch,
                rows,
                values,
            } => {
                assert_eq!(*epoch, 1);
                assert_eq!(batch.add_edges, vec![(1, 2)]);
                assert_eq!(batch.remove_edges, vec![(3, 4)]);
                assert_eq!(batch.feature_updates, vec![(5, vec![0.5, -0.25])]);
                assert_eq!(rows, &vec![2, 5]);
                let bits: Vec<u32> = values.data.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = [1.0f32, -0.0, 2.5e-8, 4.0, 5.0, -6.0]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(bits, want, "patch values survive bit-exactly (signed zero too)");
            }
            other => panic!("wrong record: {:?}", other),
        }
        match scan.records[1] {
            WalRecord::Publish { epoch, digest, .. } => {
                assert_eq!((epoch, digest), (2, 0xDEAD));
            }
            ref other => panic!("wrong record: {:?}", other),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_trimmed_and_corruption_is_an_offset_error() {
        let dir = tmp_dir("tear");
        let fs = SimFs::new(16.0);
        let path = wal_path(&dir, 0);
        {
            let mut wal = Wal::create(&dir, 0, 10, 2, 7).unwrap();
            wal.append(&sample_delta(1), &fs).unwrap();
            wal.append(&sample_delta(2), &fs).unwrap();
        }
        let full = std::fs::metadata(&path).unwrap().len();
        // tear the second record: drop its last 5 bytes
        OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(full - 5)
            .unwrap();
        let s = scan(&path).unwrap();
        assert_eq!(s.records.len(), 1, "torn record dropped, not fatal");
        assert!(s.trimmed_at.is_some());
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            s.trimmed_at.unwrap(),
            "file physically trimmed to the record boundary"
        );
        let again = scan(&path).unwrap();
        assert!(again.trimmed_at.is_none(), "trim is persistent");

        // now flip one bit inside the first record's body: corruption
        let mut bytes = std::fs::read(&path).unwrap();
        let body = WAL_HEADER_LEN as usize + REC_HEADER_LEN;
        bytes[body + 3] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = scan(&path).unwrap_err();
        let msg = format!("{:#}", err);
        assert!(
            msg.contains(&format!("offset {}", WAL_HEADER_LEN)),
            "corruption error must name the record offset: {}",
            msg
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
