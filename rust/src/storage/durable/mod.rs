//! Durable log-structured storage with crash recovery (DESIGN.md
//! §Durability).
//!
//! PR 5's spill tier dies with the process; this module is the tier
//! below it that doesn't. A [`DurableStore`] owns one directory holding,
//! per checkpoint *generation* `G`:
//!
//! ```text
//! ckpt-G.pages   raw row-major f32 table snapshot (durable PageFile)
//! ckpt-G.meta    the commit point: geometry + whole-grid digest, checksummed
//! wal-G.log      checksummed record log extending generation G
//! ```
//!
//! The **checkpoint/watermark split**: the checkpoint holds the table as
//! of its *watermark* epoch; the WAL holds everything after it. Writes
//! journal-then-publish — a delta epoch's batch *and* the row patch it
//! produced are fsync'd to the WAL before the epoch becomes visible in
//! the serving [`TableCell`](crate::serve::TableCell), and a full-refresh
//! publish compacts (checkpoint + WAL rotation) *before* the swap. A
//! crash therefore loses only epochs that were never client-visible, and
//! recovery ([`DurableStore::open`]) replays log-over-checkpoint to the
//! exact pre-crash table — bit-identical, which is how the repo's
//! determinism contract extends across process death.
//!
//! Compaction is generation-numbered rather than rename-based: a new
//! generation's files are written beside the old ones and the old
//! generation is deleted only after the new WAL exists. Every
//! irreversible step announces itself to the [`crash`] hook, and
//! `tests/recovery.rs` kills a churn schedule at every one of those
//! points in turn, proving each recovers bit-identically.

pub mod crash;

mod checkpoint;
mod wal;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cluster::metrics::StorageCounters;
use crate::coordinator::SimFs;
use crate::graph::delta::UpdateBatch;
use crate::storage::DEFAULT_SPILL_GBPS;
use crate::tensor::Matrix;
use crate::util::{fnv1a_extend, FNV_OFFSET};
use crate::Result;

pub use checkpoint::CheckpointMeta;
pub use wal::{WalRecord, WalScan, REC_HEADER_LEN, WAL_HEADER_LEN};

use crash::CrashPoint;

/// Tuning for a [`DurableStore`].
#[derive(Debug, Clone, Copy)]
pub struct DurableOptions {
    /// Compact (checkpoint + WAL rotation) after this many WAL records.
    pub compact_every: u64,
}

impl Default for DurableOptions {
    fn default() -> Self {
        DurableOptions { compact_every: 64 }
    }
}

/// FNV-1a digest of a table's geometry and exact f32 bit patterns; the
/// integrity check `Publish` WAL records carry.
pub fn table_digest(table: &Matrix) -> u64 {
    let mut h = fnv1a_extend(FNV_OFFSET, &(table.rows as u64).to_le_bytes());
    h = fnv1a_extend(h, &(table.cols as u64).to_le_bytes());
    for v in &table.data {
        h = fnv1a_extend(h, &v.to_le_bytes());
    }
    h
}

/// Directory of rank `rank`'s per-shard store under `root`. The elastic
/// membership layer (`cluster::membership`) keeps one store per rank so a
/// killed rank's band can be rebuilt from its own WAL + checkpoint
/// (`DurableStore::open`) instead of recomputed; naming is centralized
/// here so the CLI, tests, and the membership layer agree on the layout.
pub fn shard_dir(root: &Path, rank: usize) -> PathBuf {
    root.join(format!("shard-{:04}", rank))
}

/// What [`DurableStore::open`] rebuilt from disk.
pub struct Recovered {
    /// Last journaled epoch (what serving resumes at).
    pub epoch: u64,
    /// The live checkpoint's epoch (everything after it came from the WAL).
    pub watermark: u64,
    /// The recovered table: checkpoint + replayed WAL patches,
    /// bit-identical to the pre-crash state.
    pub table: Matrix,
    /// The replayed delta batches `(epoch, batch)`, oldest first — the
    /// logical audit trail (parity tests replay them through the
    /// in-memory path).
    pub deltas: Vec<(u64, UpdateBatch)>,
    /// Total WAL records replayed (deltas + publishes).
    pub records_replayed: usize,
    /// Byte offset a torn WAL tail was trimmed at, if one was found.
    pub trimmed_at: Option<u64>,
    /// Simulated I/O seconds the recovery read charged.
    pub sim_secs: f64,
}

/// A directory-rooted, WAL + checkpoint store for one serving table.
pub struct DurableStore {
    dir: PathBuf,
    fs: Arc<SimFs>,
    wal: wal::Wal,
    gen: u64,
    watermark: u64,
    last_epoch: u64,
    records_since_ckpt: u64,
    opts: DurableOptions,
    counters: StorageCounters,
    sim_secs: f64,
}

fn store_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let is_store = (name.starts_with("ckpt-")
            && (name.ends_with(".meta") || name.ends_with(".pages")))
            || (name.starts_with("wal-") && name.ends_with(".log"));
        if is_store {
            out.push(entry.path());
        }
    }
    Ok(out)
}

fn gen_of(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_string_lossy();
    name.strip_prefix("ckpt-")
        .and_then(|s| s.strip_suffix(".meta").or_else(|| s.strip_suffix(".pages")))
        .or_else(|| name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log")))
        .and_then(|s| s.parse().ok())
}

impl DurableStore {
    /// Start a fresh store in `dir` (clearing any previous store files):
    /// checkpoint `baseline` as generation 0 / epoch 0 and open an empty
    /// WAL. `seed` is the pipeline seed, echoed into every file header
    /// so a resume against the wrong config fails loudly.
    pub fn create(
        dir: &Path,
        seed: u64,
        baseline: &Matrix,
        opts: DurableOptions,
    ) -> Result<DurableStore> {
        anyhow::ensure!(opts.compact_every >= 1, "compact_every must be >= 1");
        std::fs::create_dir_all(dir)?;
        for stale in store_files(dir)? {
            std::fs::remove_file(&stale)?;
        }
        let fs = SimFs::new(DEFAULT_SPILL_GBPS);
        let mut counters = StorageCounters::default();
        let (bytes, io) = checkpoint::write(dir, 0, 0, baseline, seed, &fs)?;
        counters.checkpoints += 1;
        counters.spill_bytes_written += bytes;
        let wal = wal::Wal::create(dir, 0, baseline.rows as u64, baseline.cols, seed)?;
        counters.wal_bytes += wal.bytes_appended;
        let sim_secs = io + fs.charge(wal.bytes_appended);
        Ok(DurableStore {
            dir: dir.to_path_buf(),
            fs,
            wal,
            gen: 0,
            watermark: 0,
            last_epoch: 0,
            records_since_ckpt: 0,
            opts,
            counters,
            sim_secs,
        })
    }

    /// True when `dir` holds a store a resume could recover (at least one
    /// checkpoint meta file, committed or not — `open` decides validity).
    pub fn exists(dir: &Path) -> bool {
        checkpoint::list_gens(dir)
            .map(|g| !g.is_empty())
            .unwrap_or(false)
    }

    /// Recover: pick the newest committed generation, load and verify its
    /// checkpoint, scan its WAL (trimming a torn tail), replay the log
    /// over the checkpoint, verify any `Publish` digest against the
    /// rebuilt table, clean stale generations, and reopen for appending.
    pub fn open(dir: &Path, opts: DurableOptions) -> Result<(DurableStore, Recovered)> {
        anyhow::ensure!(opts.compact_every >= 1, "compact_every must be >= 1");
        let fs = SimFs::new(DEFAULT_SPILL_GBPS);
        let gens = checkpoint::list_gens(dir)?;
        anyhow::ensure!(!gens.is_empty(), "no durable store in {:?}", dir);
        // newest generation whose commit (meta) is valid; an invalid meta
        // is a crashed commit — fall back, never fail, unless nothing at
        // all committed
        let mut live = None;
        for &g in &gens {
            if let Ok(meta) = checkpoint::read_meta(dir, g) {
                live = Some((g, meta));
                break;
            }
        }
        let (gen, meta) =
            live.ok_or_else(|| anyhow::anyhow!("no committed checkpoint generation in {:?}", dir))?;
        let (_, mut table, ckpt_io) = checkpoint::read(dir, gen, &fs)?;
        let mut counters = StorageCounters::default();
        counters.recoveries += 1;
        counters.spill_bytes_read += table.nbytes();
        let mut sim_secs = ckpt_io;

        // scan + replay the generation's WAL (absent = crashed between
        // commit and rotation: an empty log, recreated below)
        let wpath = wal::wal_path(dir, gen);
        let (records, trimmed_at, scanned) = if wpath.exists() {
            let scan = wal::scan(&wpath)?;
            anyhow::ensure!(
                scan.gen == gen
                    && scan.dim == meta.cols as usize
                    && scan.n_nodes == meta.rows
                    && scan.seed == meta.seed,
                "wal {:?} does not match checkpoint gen {} (gen/dim/nodes/seed {:?} vs ({}, {}, {}, {}))",
                wpath,
                gen,
                (scan.gen, scan.dim, scan.n_nodes, scan.seed),
                gen,
                meta.cols,
                meta.rows,
                meta.seed
            );
            counters.spill_bytes_read += scan.bytes;
            sim_secs += fs.charge(scan.bytes);
            (scan.records, scan.trimmed_at, true)
        } else {
            (Vec::new(), None, false)
        };

        let mut epoch = meta.epoch;
        let mut deltas = Vec::new();
        let records_replayed = records.len();
        for rec in records {
            // journal_* sequences epochs: a Delta is always the next
            // epoch; a Publish seals the compaction that just rotated
            // this WAL, so it carries the checkpoint's own epoch.
            let expected_next = match &rec {
                WalRecord::Delta { .. } => rec.epoch() == epoch + 1,
                WalRecord::Publish { .. } => rec.epoch() == epoch,
            };
            anyhow::ensure!(
                expected_next,
                "wal {:?}: epoch {} replayed after epoch {} (log out of order)",
                wpath,
                rec.epoch(),
                epoch
            );
            match rec {
                WalRecord::Delta {
                    epoch: e,
                    batch,
                    rows,
                    values,
                } => {
                    for (i, &r) in rows.iter().enumerate() {
                        anyhow::ensure!(
                            (r as usize) < table.rows,
                            "wal {:?}: patch row {} outside table of {} rows",
                            wpath,
                            r,
                            table.rows
                        );
                        table.row_mut(r as usize).copy_from_slice(values.row(i));
                    }
                    deltas.push((e, batch));
                    epoch = e;
                }
                WalRecord::Publish {
                    epoch: e, digest, ..
                } => {
                    // the table this publish swapped in is the checkpoint
                    // this WAL extends; re-verify it end to end
                    anyhow::ensure!(
                        digest == table_digest(&table),
                        "wal {:?}: publish at epoch {} digests {:#018x}, recovered table {:#018x}",
                        wpath,
                        e,
                        digest,
                        table_digest(&table)
                    );
                    epoch = e;
                }
            }
        }

        // stale generations (and any uncommitted debris) are dead weight
        for stale in store_files(dir)? {
            if gen_of(&stale) != Some(gen) {
                std::fs::remove_file(&stale)?;
            }
        }

        let wal = if scanned {
            let scan_again = WalScan {
                gen,
                n_nodes: meta.rows,
                dim: meta.cols as usize,
                seed: meta.seed,
                records: Vec::new(),
                trimmed_at: None,
                bytes: 0,
            };
            let mut w = wal::Wal::open_for_append(&wpath, &scan_again)?;
            w.records = records_replayed as u64;
            w
        } else {
            wal::Wal::create(dir, gen, meta.rows, meta.cols as usize, meta.seed)?
        };

        let store = DurableStore {
            dir: dir.to_path_buf(),
            fs,
            wal,
            gen,
            watermark: meta.epoch,
            last_epoch: epoch,
            records_since_ckpt: records_replayed as u64,
            opts,
            counters,
            sim_secs,
        };
        let recovered = Recovered {
            epoch,
            watermark: meta.epoch,
            table,
            deltas,
            records_replayed,
            trimmed_at,
            sim_secs,
        };
        Ok((store, recovered))
    }

    /// Journal one delta epoch — the batch and the patch it produced —
    /// fsync'd before the caller publishes the epoch. `epoch` must be
    /// exactly `last_epoch + 1` (the journal is the epoch sequencer).
    pub fn journal_delta(
        &mut self,
        epoch: u64,
        batch: &UpdateBatch,
        rows: &[u32],
        values: &Matrix,
    ) -> Result<()> {
        anyhow::ensure!(
            epoch == self.last_epoch + 1,
            "journal_delta: epoch {} after {}",
            epoch,
            self.last_epoch
        );
        let rec = WalRecord::Delta {
            epoch,
            batch: batch.clone(),
            rows: rows.to_vec(),
            values: values.clone(),
        };
        let (bytes, io) = self.wal.append(&rec, &self.fs)?;
        self.counters.wal_bytes += bytes;
        self.sim_secs += io;
        self.records_since_ckpt += 1;
        self.last_epoch = epoch;
        Ok(())
    }

    /// Journal a snapshot mark: append a `Publish` record carrying the
    /// table digest for the epoch that was *just journaled* — without
    /// compacting. The temporal engine (`crate::temporal`) marks every
    /// sealed epoch this way, so the WAL keeps the full delta history a
    /// time-travel replay needs ([`read_history`]) while each published
    /// snapshot's digest is still durably committed; `journal_publish`
    /// would fold the history into a checkpoint and destroy replayability.
    pub fn journal_mark(&mut self, epoch: u64, table: &Matrix) -> Result<()> {
        anyhow::ensure!(
            epoch == self.last_epoch,
            "journal_mark: epoch {} is not the journaled epoch {}",
            epoch,
            self.last_epoch
        );
        let rec = WalRecord::Publish {
            epoch,
            digest: table_digest(table),
            rows: table.rows as u64,
            dim: table.cols as u32,
        };
        let (bytes, io) = self.wal.append(&rec, &self.fs)?;
        self.counters.wal_bytes += bytes;
        self.sim_secs += io;
        self.records_since_ckpt += 1;
        Ok(())
    }

    /// Journal a full-table publish: compact (checkpoint `table` at
    /// `epoch`, rotate the WAL) *then* append the `Publish` record
    /// carrying the table digest. Called before the serving swap, so a
    /// crash anywhere in here loses nothing a client ever saw.
    pub fn journal_publish(&mut self, epoch: u64, table: &Matrix) -> Result<()> {
        anyhow::ensure!(
            epoch == self.last_epoch + 1,
            "journal_publish: epoch {} after {}",
            epoch,
            self.last_epoch
        );
        self.compact(epoch, table)?;
        let rec = WalRecord::Publish {
            epoch,
            digest: table_digest(table),
            rows: table.rows as u64,
            dim: table.cols as u32,
        };
        let (bytes, io) = self.wal.append(&rec, &self.fs)?;
        self.counters.wal_bytes += bytes;
        self.sim_secs += io;
        self.records_since_ckpt += 1;
        self.last_epoch = epoch;
        Ok(())
    }

    /// True when the WAL has grown past `compact_every` records since the
    /// live checkpoint.
    pub fn should_compact(&self) -> bool {
        self.records_since_ckpt >= self.opts.compact_every
    }

    /// Compact: checkpoint `table` at `epoch` as generation `gen + 1`,
    /// rotate to a fresh WAL, delete the old generation. Crash points:
    /// every checkpoint page write, the commit, the rotation, the
    /// cleanup; a crash at any of them recovers to either the old or the
    /// new generation — both bit-identical to a table the caller held.
    pub fn compact(&mut self, epoch: u64, table: &Matrix) -> Result<()> {
        anyhow::ensure!(
            epoch >= self.last_epoch,
            "compact: epoch {} behind journaled {}",
            epoch,
            self.last_epoch
        );
        anyhow::ensure!(
            table.rows as u64 == self.wal.n_nodes && table.cols == self.wal.dim,
            "compact: table {}x{} does not match store {}x{}",
            table.rows,
            table.cols,
            self.wal.n_nodes,
            self.wal.dim
        );
        let next = self.gen + 1;
        let (bytes, io) =
            checkpoint::write(&self.dir, next, epoch, table, self.wal.seed, &self.fs)?;
        self.counters.checkpoints += 1;
        self.counters.spill_bytes_written += bytes;
        self.sim_secs += io;

        crash::step(CrashPoint::WalRotate)?;
        let wal = wal::Wal::create(&self.dir, next, self.wal.n_nodes, self.wal.dim, self.wal.seed)?;
        self.counters.wal_bytes += wal.bytes_appended;
        self.sim_secs += self.fs.charge(wal.bytes_appended);
        self.wal = wal;

        crash::step(CrashPoint::Cleanup)?;
        for stale in store_files(&self.dir)? {
            if gen_of(&stale) != Some(next) {
                std::fs::remove_file(&stale)?;
            }
        }
        self.gen = next;
        self.watermark = epoch;
        self.last_epoch = epoch;
        self.records_since_ckpt = 0;
        Ok(())
    }

    /// Directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Live checkpoint generation.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Epoch of the live checkpoint.
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Latest journaled epoch.
    pub fn last_epoch(&self) -> u64 {
        self.last_epoch
    }

    /// Records in the live WAL (replayed + appended).
    pub fn wal_records(&self) -> u64 {
        self.wal.records
    }

    /// Pipeline seed echoed through every store file — resume validates
    /// it against the run config.
    pub fn seed(&self) -> u64 {
        self.wal.seed
    }

    /// Durability counters (WAL bytes, checkpoints, recoveries, spill
    /// traffic) for rolling into a machine's metrics.
    pub fn counters(&self) -> StorageCounters {
        self.counters.clone()
    }

    /// Simulated I/O seconds this store has charged so far.
    pub fn sim_secs(&self) -> f64 {
        self.sim_secs
    }
}

/// Read-only view of a store's epoch history: the live checkpoint plus
/// every journaled delta and snapshot mark after it, in epoch order.
/// Unlike [`DurableStore::open`] this touches nothing on disk — no WAL
/// reopen, no stale-generation cleanup — so it can run against a store
/// another process (or a live [`DurableStore`]) still owns.
pub struct EpochHistory {
    /// Epoch of the checkpoint `baseline` holds (the watermark).
    pub baseline_epoch: u64,
    /// The checkpoint table — the state as of `baseline_epoch`.
    pub baseline: Matrix,
    /// Pipeline seed echoed through the store files.
    pub seed: u64,
    /// Journaled deltas after the checkpoint: `(epoch, batch, patched
    /// rows, patch values)`, oldest first.
    pub deltas: Vec<(u64, UpdateBatch, Vec<u32>, Matrix)>,
    /// Snapshot marks: `(epoch, table digest)` per `Publish` record.
    pub published: Vec<(u64, u64)>,
}

impl EpochHistory {
    /// Scan `dir`'s newest committed generation without mutating it.
    pub fn read(dir: &Path) -> Result<EpochHistory> {
        let gens = checkpoint::list_gens(dir)?;
        anyhow::ensure!(!gens.is_empty(), "no durable store in {:?}", dir);
        let mut live = None;
        for &g in &gens {
            if let Ok(meta) = checkpoint::read_meta(dir, g) {
                live = Some((g, meta));
                break;
            }
        }
        let (gen, meta) =
            live.ok_or_else(|| anyhow::anyhow!("no committed checkpoint generation in {:?}", dir))?;
        let fs = SimFs::new(DEFAULT_SPILL_GBPS);
        let (_, baseline, _) = checkpoint::read(dir, gen, &fs)?;
        let wpath = wal::wal_path(dir, gen);
        let mut deltas = Vec::new();
        let mut published = Vec::new();
        if wpath.exists() {
            let scan = wal::scan(&wpath)?;
            anyhow::ensure!(
                scan.gen == gen && scan.seed == meta.seed,
                "wal {:?} does not match checkpoint gen {}",
                wpath,
                gen
            );
            for rec in scan.records {
                match rec {
                    WalRecord::Delta { epoch, batch, rows, values } => {
                        deltas.push((epoch, batch, rows, values));
                    }
                    WalRecord::Publish { epoch, digest, .. } => {
                        published.push((epoch, digest));
                    }
                }
            }
        }
        Ok(EpochHistory {
            baseline_epoch: meta.epoch,
            baseline,
            seed: meta.seed,
            deltas,
            published,
        })
    }

    /// Last journaled epoch in the history.
    pub fn last_epoch(&self) -> u64 {
        self.deltas.last().map_or(self.baseline_epoch, |(e, ..)| *e)
    }

    /// Reconstruct the table as of `epoch` by replaying the journaled
    /// patches over the checkpoint, verifying the snapshot-mark digest
    /// when one was journaled for that epoch — the time-travel read path
    /// for epochs whose resident snapshot was evicted.
    pub fn replay_to(&self, epoch: u64) -> Result<Matrix> {
        anyhow::ensure!(
            epoch >= self.baseline_epoch,
            "epoch {} predates the checkpoint watermark {} — compacted away",
            epoch,
            self.baseline_epoch
        );
        anyhow::ensure!(
            epoch <= self.last_epoch(),
            "epoch {} is ahead of the journaled history (last epoch {})",
            epoch,
            self.last_epoch()
        );
        let mut table = self.baseline.clone();
        for (e, _, rows, values) in &self.deltas {
            if *e > epoch {
                break;
            }
            for (i, &r) in rows.iter().enumerate() {
                anyhow::ensure!(
                    (r as usize) < table.rows,
                    "history patch row {} outside table of {} rows",
                    r,
                    table.rows
                );
                table.row_mut(r as usize).copy_from_slice(values.row(i));
            }
        }
        if let Some(&(_, digest)) = self.published.iter().find(|(e, _)| *e == epoch) {
            anyhow::ensure!(
                digest == table_digest(&table),
                "replay to epoch {} digests {:#018x}, journaled mark says {:#018x}",
                epoch,
                table_digest(&table),
                digest
            );
        }
        Ok(table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("deal-durable-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn patch(table: &mut Matrix, rows: &[u32], values: &Matrix) {
        for (i, &r) in rows.iter().enumerate() {
            table.row_mut(r as usize).copy_from_slice(values.row(i));
        }
    }

    #[test]
    fn create_journal_reopen_replays_to_the_exact_table() {
        let dir = tmp_dir("basic");
        let mut table = Matrix::from_vec(4, 2, vec![0.5; 8]);
        let mut store =
            DurableStore::create(&dir, 42, &table, DurableOptions::default()).unwrap();
        assert!(DurableStore::exists(&dir));
        assert!(store.counters().checkpoints == 1 && store.counters().wal_bytes > 0);
        assert!(store.sim_secs() > 0.0, "durability costs simulated time");

        let rows = vec![1u32, 3];
        let values = Matrix::from_vec(2, 2, vec![9.0, -0.0, 3.5, 1.25e-9]);
        store
            .journal_delta(1, &UpdateBatch::default(), &rows, &values)
            .unwrap();
        patch(&mut table, &rows, &values);
        // out-of-order epochs are rejected
        assert!(store
            .journal_delta(5, &UpdateBatch::default(), &[], &Matrix::zeros(0, 2))
            .is_err());
        drop(store);

        let (store, rec) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
        assert_eq!((rec.epoch, rec.watermark, rec.records_replayed), (1, 0, 1));
        assert_eq!(rec.deltas.len(), 1);
        let a: Vec<u32> = table.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = rec.table.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "log-over-checkpoint replay is bit-identical");
        assert_eq!(store.counters().recoveries, 1);
        assert_eq!((store.last_epoch(), store.generation()), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn publish_compacts_rotates_and_cleans() {
        let dir = tmp_dir("publish");
        let t0 = Matrix::from_vec(3, 2, vec![1.0; 6]);
        let mut store = DurableStore::create(&dir, 7, &t0, DurableOptions::default()).unwrap();
        let t1 = Matrix::from_vec(3, 2, vec![2.0; 6]);
        store.journal_publish(1, &t1).unwrap();
        assert_eq!((store.generation(), store.watermark(), store.last_epoch()), (1, 1, 1));
        assert!(
            !wal::wal_path(&dir, 0).exists() && !checkpoint::meta_path(&dir, 0).exists(),
            "old generation cleaned"
        );
        let (_, rec) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
        assert_eq!((rec.epoch, rec.watermark), (1, 1));
        assert_eq!(rec.table.data, t1.data);
        assert_eq!(rec.records_replayed, 1, "the publish record is in the new wal");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_mark_keeps_history_replayable() {
        let dir = tmp_dir("mark");
        let t0 = Matrix::from_vec(4, 2, vec![0.25; 8]);
        let mut store = DurableStore::create(
            &dir,
            11,
            &t0,
            DurableOptions { compact_every: u64::MAX },
        )
        .unwrap();
        let mut table = t0.clone();
        let mut snapshots = vec![t0.clone()];
        for e in 1..=3u64 {
            let rows = vec![(e % 4) as u32];
            let values = Matrix::from_vec(1, 2, vec![e as f32, -(e as f32)]);
            store.journal_delta(e, &UpdateBatch::default(), &rows, &values).unwrap();
            patch(&mut table, &rows, &values);
            store.journal_mark(e, &table).unwrap();
            snapshots.push(table.clone());
        }
        // a mark for an epoch that isn't the journaled one is rejected
        assert!(store.journal_mark(7, &table).is_err());
        drop(store);

        let hist = EpochHistory::read(&dir).unwrap();
        assert_eq!((hist.baseline_epoch, hist.last_epoch()), (0, 3));
        assert_eq!(hist.deltas.len(), 3);
        assert_eq!(hist.published.len(), 3);
        for (e, want) in snapshots.iter().enumerate() {
            let got = hist.replay_to(e as u64).unwrap();
            assert_eq!(&got, want, "replay to epoch {} diverged", e);
        }
        assert!(hist.replay_to(9).is_err(), "future epochs are rejected");

        // a normal reopen also replays the marked WAL cleanly
        let (_, rec) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
        assert_eq!(rec.epoch, 3);
        assert_eq!(rec.table, table);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn should_compact_follows_the_record_budget() {
        let dir = tmp_dir("budget");
        let t = Matrix::from_vec(2, 2, vec![0.0; 4]);
        let mut store =
            DurableStore::create(&dir, 1, &t, DurableOptions { compact_every: 2 }).unwrap();
        let v = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        store.journal_delta(1, &UpdateBatch::default(), &[0], &v).unwrap();
        assert!(!store.should_compact());
        store.journal_delta(2, &UpdateBatch::default(), &[1], &v).unwrap();
        assert!(store.should_compact());
        let mut full = t.clone();
        full.row_mut(0).copy_from_slice(&v.data);
        full.row_mut(1).copy_from_slice(&v.data);
        store.compact(2, &full).unwrap();
        assert!(!store.should_compact());
        assert_eq!(store.counters().checkpoints, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
