//! Deterministic crash-point injection for the durable store.
//!
//! Durability claims are only as good as the crashes they survive, so the
//! fault hook is part of the subsystem, not the test suite: every
//! irreversible step of the log-structured store — each WAL append, each
//! checkpoint page write, the checkpoint commit, the WAL rotation, the
//! old-generation cleanup — calls [`step`] before doing its work. Arming
//! the hook with [`arm`]`(n)` makes the `n`-th step on this thread fail
//! with a [`CrashInjected`] error instead of completing, which is how
//! `tests/recovery.rs` kills a run at *every* crash point in turn and
//! proves recovery is bit-identical from each one.
//!
//! The counter is thread-local, so parallel test binaries never perturb
//! each other, and the schedule is a plain count — same run, same points,
//! every time (the repo's determinism contract extended to its faults).
//! A WAL-append injection additionally writes *half* the record before
//! failing, so the on-disk state is a genuinely torn write, not a clean
//! absence.

use std::cell::Cell;
use std::fmt;

/// The irreversible steps the durable store announces to the fault hook.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPoint {
    /// A WAL record append (torn: half the record reaches the disk).
    WalAppend,
    /// One page write of a checkpoint under construction.
    CheckpointWrite,
    /// The checkpoint commit (meta-file write) that makes a generation live.
    CheckpointCommit,
    /// Creation of the fresh WAL after a checkpoint commit.
    WalRotate,
    /// Deletion of the previous generation's files.
    Cleanup,
}

impl CrashPoint {
    /// Stable name for messages and logs.
    pub fn name(self) -> &'static str {
        match self {
            CrashPoint::WalAppend => "wal-append",
            CrashPoint::CheckpointWrite => "checkpoint-write",
            CrashPoint::CheckpointCommit => "checkpoint-commit",
            CrashPoint::WalRotate => "wal-rotate",
            CrashPoint::Cleanup => "cleanup",
        }
    }
}

/// The error an armed crash point fails with. Distinguishable from real
/// I/O errors via [`is_injected`], so tests can assert the *right* crash
/// happened.
#[derive(Debug)]
pub struct CrashInjected {
    /// Which step was killed.
    pub point: CrashPoint,
    /// 1-based ordinal of the step since [`arm`]/[`reset_count`].
    pub ordinal: u64,
}

impl fmt::Display for CrashInjected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected crash at {} (step {})",
            self.point.name(),
            self.ordinal
        )
    }
}

impl std::error::Error for CrashInjected {}

thread_local! {
    // 0 = disarmed; otherwise the 1-based step ordinal to kill.
    static TARGET: Cell<u64> = const { Cell::new(0) };
    static COUNTER: Cell<u64> = const { Cell::new(0) };
}

/// Arm the hook: the `nth` (1-based) crash point stepped on this thread
/// after this call fails with [`CrashInjected`]. Resets the step counter.
pub fn arm(nth: u64) {
    assert!(nth >= 1, "crash points are 1-based");
    COUNTER.with(|c| c.set(0));
    TARGET.with(|t| t.set(nth));
}

/// Disarm the hook (crash points become no-ops again).
pub fn disarm() {
    TARGET.with(|t| t.set(0));
}

/// Reset the step counter without changing the armed target. Used to
/// exclude setup work (e.g. store creation) from a sweep's numbering.
pub fn reset_count() {
    COUNTER.with(|c| c.set(0));
}

/// Crash points stepped on this thread since the last [`arm`] /
/// [`reset_count`]. A disarmed full run measures the sweep's extent.
pub fn count() -> u64 {
    COUNTER.with(|c| c.get())
}

/// Announce an irreversible step. Returns `Err(CrashInjected)` when this
/// is the armed step, `Ok(())` otherwise (including when disarmed — the
/// counter still advances so [`count`] stays meaningful).
pub(crate) fn step(point: CrashPoint) -> crate::Result<()> {
    let ordinal = COUNTER.with(|c| {
        let v = c.get() + 1;
        c.set(v);
        v
    });
    let target = TARGET.with(|t| t.get());
    if target != 0 && ordinal == target {
        return Err(anyhow::Error::new(CrashInjected { point, ordinal }));
    }
    Ok(())
}

/// True when `err` (anywhere in its chain) is an injected crash rather
/// than a real failure.
pub fn is_injected(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|e| e.downcast_ref::<CrashInjected>().is_some())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_kills_exactly_the_nth_step() {
        arm(3);
        assert!(step(CrashPoint::WalAppend).is_ok());
        assert!(step(CrashPoint::CheckpointWrite).is_ok());
        let err = step(CrashPoint::CheckpointCommit).unwrap_err();
        assert!(is_injected(&err));
        let inj = err.downcast_ref::<CrashInjected>().unwrap();
        assert_eq!((inj.point, inj.ordinal), (CrashPoint::CheckpointCommit, 3));
        // past the target: steps succeed again
        assert!(step(CrashPoint::WalRotate).is_ok());
        assert_eq!(count(), 4);
        disarm();
        arm(1);
        assert!(step(CrashPoint::Cleanup).is_err(), "re-arm resets the counter");
        disarm();
    }

    #[test]
    fn disarmed_steps_count_but_never_fail() {
        disarm();
        reset_count();
        for _ in 0..5 {
            step(CrashPoint::WalAppend).unwrap();
        }
        assert_eq!(count(), 5);
        let real = anyhow::anyhow!("disk on fire");
        assert!(!is_injected(&real));
    }
}
