//! The typed tiers over the page cache: [`PagedMatrix`] (feature and
//! activation rows) and [`PagedCsr`] (layer-graph adjacency bands).
//!
//! Both are thin descriptors — the bytes live in a [`PageCache`]-owned
//! [`PageFile`](super::PageFile) — so they are `Copy`-cheap to pass
//! around and safe to share with a feature-server thread alongside a
//! [`SharedPageCache`] clone.
//!
//! [`PagedCsr`] keeps its `indptr` index RAM-resident (8 bytes per row —
//! every out-of-core graph system keeps the index hot) and pages the
//! edge payload as an `n_edges × 2` grid of `[source-id bits, weight]`
//! rows: node ids travel as `f32::from_bits` bit patterns, which the
//! page file round-trips exactly (no float arithmetic ever touches
//! them).

use crate::graph::{Csr, NodeId};
use crate::tensor::Matrix;
use crate::Result;

use super::cache::{FileId, PageCache, SharedPageCache};

/// A `rows × cols` f32 matrix stored in row-band pages behind a cache.
#[derive(Clone, Copy, Debug)]
pub struct PagedMatrix {
    pub file: FileId,
    pub rows: usize,
    pub cols: usize,
    pub page_rows: usize,
}

impl PagedMatrix {
    /// A zero-filled paged matrix.
    pub fn create(
        cache: &mut PageCache,
        tag: &str,
        rows: usize,
        cols: usize,
        page_rows: usize,
        fs: std::sync::Arc<crate::coordinator::SimFs>,
    ) -> Result<PagedMatrix> {
        let page_rows = page_rows.max(1);
        let file = cache.create_file(tag, rows, cols, page_rows, fs)?;
        Ok(PagedMatrix { file, rows, cols, page_rows })
    }

    /// Stage a resident matrix into a paged one, page by page (the pages
    /// land dirty in the cache and spill to disk under budget pressure or
    /// on flush — a working set larger than the budget streams through).
    pub fn from_matrix(
        cache: &mut PageCache,
        tag: &str,
        m: &Matrix,
        page_rows: usize,
        fs: std::sync::Arc<crate::coordinator::SimFs>,
    ) -> Result<PagedMatrix> {
        let pm = PagedMatrix::create(cache, tag, m.rows, m.cols, page_rows, fs)?;
        for p in 0..pm.n_pages() {
            let (lo, hi) = pm.page_row_range(p);
            cache.write_page(pm.file, p, &m.data[lo * m.cols..hi * m.cols])?;
        }
        Ok(pm)
    }

    /// Number of pages.
    pub fn n_pages(&self) -> usize {
        self.rows.div_ceil(self.page_rows)
    }

    /// Row range `[lo, hi)` covered by page `p`.
    pub fn page_row_range(&self, p: usize) -> (usize, usize) {
        let lo = p * self.page_rows;
        (lo, (lo + self.page_rows).min(self.rows))
    }

    /// Total bytes of the full grid (on the spill device).
    pub fn nbytes(&self) -> u64 {
        (self.rows * self.cols * 4) as u64
    }

    /// Bytes of one full page (the residency granularity).
    pub fn page_nbytes(&self) -> u64 {
        (self.page_rows * self.cols * 4) as u64
    }

    /// Write one row through the cache.
    pub fn write_row(&self, cache: &mut PageCache, r: usize, row: &[f32]) -> Result<()> {
        cache.write_row(self.file, r, row)
    }

    /// Write rows `[at, at + block.rows)` through the cache, page-aligned
    /// writes taking the overwrite fast path.
    pub fn write_rows(&self, cache: &mut PageCache, at: usize, block: &Matrix) -> Result<()> {
        anyhow::ensure!(block.cols == self.cols, "width mismatch");
        anyhow::ensure!(at + block.rows <= self.rows, "rows overrun");
        let mut r = 0;
        while r < block.rows {
            let gr = at + r;
            let page = gr / self.page_rows;
            let (plo, phi) = self.page_row_range(page);
            if gr == plo && at + block.rows >= phi {
                // whole page covered: overwrite without faulting
                cache.write_page(self.file, page, &block.data[r * self.cols..(r + phi - plo) * self.cols])?;
                r += phi - plo;
            } else {
                cache.write_row(self.file, gr, block.row(r))?;
                r += 1;
            }
        }
        Ok(())
    }

    /// Copy row `r` into `out`.
    pub fn row_copy(&self, cache: &mut PageCache, r: usize, out: &mut [f32]) -> Result<()> {
        cache.copy_row(self.file, r, out)
    }

    /// Gather rows by index into a resident matrix (the paged twin of
    /// `Matrix::gather_rows` — same output for the same indices).
    pub fn gather(&self, cache: &mut PageCache, idx: &[usize]) -> Result<Matrix> {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            cache.copy_row(self.file, r, out.row_mut(i))?;
        }
        Ok(out)
    }

    /// Assemble rows `[lo, hi)` into a resident matrix.
    pub fn band(&self, cache: &mut PageCache, lo: usize, hi: usize) -> Result<Matrix> {
        anyhow::ensure!(lo <= hi && hi <= self.rows, "bad band {}..{}", lo, hi);
        let mut out = Matrix::zeros(hi - lo, self.cols);
        for r in lo..hi {
            cache.copy_row(self.file, r, out.row_mut(r - lo))?;
        }
        Ok(out)
    }

    /// Assemble the whole grid (tests / spilled-shard materialization).
    pub fn to_matrix(&self, cache: &mut PageCache) -> Result<Matrix> {
        self.band(cache, 0, self.rows)
    }

    // ---- SharedPageCache conveniences: lock, operate, drain I/O --------

    /// [`PagedMatrix::gather`] through a shared cache; returns the
    /// simulated I/O seconds this call incurred (charge them to the
    /// calling thread's clock).
    pub fn gather_shared(&self, cache: &SharedPageCache, idx: &[usize]) -> Result<(Matrix, f64)> {
        cache.with(|c| {
            let m = self.gather(c, idx)?;
            Ok((m, c.take_io_secs()))
        })
    }

    /// [`PagedMatrix::band`] through a shared cache (+ I/O seconds).
    pub fn band_shared(&self, cache: &SharedPageCache, lo: usize, hi: usize) -> Result<(Matrix, f64)> {
        cache.with(|c| {
            let m = self.band(c, lo, hi)?;
            Ok((m, c.take_io_secs()))
        })
    }
}

/// A CSR whose adjacency (source ids + per-edge weights) lives in paged
/// row bands; the `indptr` index stays resident.
#[derive(Clone, Debug)]
pub struct PagedCsr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Resident row index: edges of row `r` are `indptr[r]..indptr[r+1]`.
    pub indptr: Vec<u64>,
    /// `n_edges × 2` paged grid of `[source-id bits, weight]`.
    pub edges: PagedMatrix,
}

impl PagedCsr {
    /// Stage a resident CSR (+ aligned per-edge weights) into the paged
    /// form. `edges_per_page` is the adjacency band granularity.
    pub fn from_csr(
        cache: &mut PageCache,
        tag: &str,
        g: &Csr,
        weights: &[f32],
        edges_per_page: usize,
        fs: std::sync::Arc<crate::coordinator::SimFs>,
    ) -> Result<PagedCsr> {
        anyhow::ensure!(weights.len() == g.n_edges(), "weights misaligned with edges");
        let edges =
            PagedMatrix::create(cache, tag, g.n_edges(), 2, edges_per_page.max(1), fs)?;
        for p in 0..edges.n_pages() {
            let (lo, hi) = edges.page_row_range(p);
            let mut data = Vec::with_capacity((hi - lo) * 2);
            for e in lo..hi {
                data.push(f32::from_bits(g.indices[e]));
                data.push(weights[e]);
            }
            cache.write_page(edges.file, p, &data)?;
        }
        Ok(PagedCsr {
            n_rows: g.n_rows,
            n_cols: g.n_cols,
            indptr: g.indptr.clone(),
            edges,
        })
    }

    /// Total edge count.
    pub fn n_edges(&self) -> usize {
        self.edges.rows
    }

    /// Fetch row `r`'s adjacency into `srcs`/`ws` (cleared first), in CSR
    /// order — the same source order the resident CSR iterates, so
    /// accumulation over these edges is bit-identical to the in-memory
    /// loop. Edges are copied out one touched *page frame* at a time
    /// (O(pages) cache operations per row, not O(edges)).
    pub fn row_edges(
        &self,
        cache: &mut PageCache,
        r: usize,
        srcs: &mut Vec<NodeId>,
        ws: &mut Vec<f32>,
    ) -> Result<()> {
        srcs.clear();
        ws.clear();
        let (lo, hi) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
        let mut e = lo;
        while e < hi {
            let page = e / self.edges.page_rows;
            let (plo, phi) = self.edges.page_row_range(page);
            let pend = hi.min(phi);
            let frame = cache.read_page(self.edges.file, page)?;
            for k in e..pend {
                let off = (k - plo) * 2;
                srcs.push(frame[off].to_bits());
                ws.push(frame[off + 1]);
            }
            e = pend;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SimFs;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn fs() -> Arc<SimFs> {
        SimFs::new(crate::storage::DEFAULT_SPILL_GBPS)
    }

    #[test]
    fn matrix_roundtrip_and_band_bits() {
        let mut rng = Rng::new(77);
        let mut m = Matrix::random(33, 5, 1.0, &mut rng);
        m.set(0, 0, -0.0); // sign of zero must survive
        m.set(7, 3, f32::MIN_POSITIVE / 4.0); // subnormal
        for page_rows in [1usize, 4, 64] {
            let mut cache = PageCache::new(3 * (page_rows * 5 * 4) as u64);
            let pm = PagedMatrix::from_matrix(&mut cache, "rt", &m, page_rows, fs()).unwrap();
            let back = pm.to_matrix(&mut cache).unwrap();
            let a: Vec<u32> = m.data.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = back.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "page_rows={}", page_rows);
            let band = pm.band(&mut cache, 5, 19).unwrap();
            assert_eq!(band, m.slice_rows(5, 19));
            let gathered = pm.gather(&mut cache, &[31, 0, 7, 7]).unwrap();
            assert_eq!(gathered, m.gather_rows(&[31, 0, 7, 7]));
        }
    }

    #[test]
    fn write_rows_spans_pages() {
        let mut cache = PageCache::new(0);
        let pm = PagedMatrix::create(&mut cache, "wr", 10, 2, 4, fs()).unwrap();
        let mut rng = Rng::new(5);
        let block = Matrix::random(7, 2, 1.0, &mut rng);
        pm.write_rows(&mut cache, 2, &block).unwrap(); // straddles pages 0..2
        let full = pm.to_matrix(&mut cache).unwrap();
        assert_eq!(full.slice_rows(2, 9), block);
        assert_eq!(full.row(0), &[0.0, 0.0]);
        assert_eq!(full.row(9), &[0.0, 0.0]);
    }

    #[test]
    fn paged_csr_matches_resident_csr() {
        let edges: Vec<(NodeId, NodeId)> =
            vec![(1, 0), (2, 0), (0, 1), (2, 2), (1, 2), (0, 2), (2, 0)];
        let g = Csr::from_edges(3, &edges);
        let w: Vec<f32> = (0..g.n_edges()).map(|e| 0.5 + e as f32).collect();
        for epp in [1usize, 3, 100] {
            let mut cache = PageCache::new(4 * (epp * 2 * 4) as u64);
            let pg = PagedCsr::from_csr(&mut cache, "csr", &g, &w, epp, fs()).unwrap();
            assert_eq!(pg.n_edges(), g.n_edges());
            let (mut srcs, mut ws) = (Vec::new(), Vec::new());
            for r in 0..g.n_rows {
                pg.row_edges(&mut cache, r, &mut srcs, &mut ws).unwrap();
                let (lo, hi) = (g.indptr[r] as usize, g.indptr[r + 1] as usize);
                assert_eq!(srcs, &g.indices[lo..hi], "row {} (epp {})", r, epp);
                assert_eq!(ws, &w[lo..hi]);
            }
        }
    }

    #[test]
    fn shared_helpers_report_io() {
        let shared = SharedPageCache::new(0);
        let mut rng = Rng::new(9);
        let m = Matrix::random(16, 4, 1.0, &mut rng);
        let pm = shared
            .with(|c| PagedMatrix::from_matrix(c, "sh", &m, 4, fs()))
            .unwrap();
        // flush + drop so reads must fault (and therefore cost I/O)
        shared.with(|c| {
            c.flush().unwrap();
            c.drop_all_frames();
            let _ = c.take_io_secs();
        });
        let (band, io) = pm.band_shared(&shared, 0, 8).unwrap();
        assert_eq!(band, m.slice_rows(0, 8));
        assert!(io > 0.0, "cold band read must charge simulated I/O");
        let (again, io2) = pm.band_shared(&shared, 0, 8).unwrap();
        assert_eq!(again, band);
        assert_eq!(io2, 0.0, "warm re-read is free");
    }
}
