//! The budgeted page cache over one rank's [`PageFile`]s.
//!
//! [`PageCache`] owns a set of page files and a byte-budgeted pool of
//! decoded page frames. Eviction is **deterministic logical-clock LRU**:
//! every hit or fault stamps the frame with a monotonically increasing
//! tick; when the budget forces an eviction the minimum-stamp frame goes.
//! LRU is a stack algorithm (Mattson's inclusion property), so for a fixed
//! access sequence the fault count is monotone non-increasing as the
//! budget grows — clock/second-chance policies can exhibit Belady's
//! anomaly, which would break the budget-sweep contract `tests/storage.rs`
//! pins.
//!
//! Peak residency is bounded by construction: room is made *before* a
//! page loads (evict-until-fit), so resident bytes never exceed
//! `max(budget, page_bytes) + page_bytes` transiently — "budget plus one
//! page per active stream", since a shared cache serializes loads behind
//! its mutex.
//!
//! Dirty frames (written through [`PageCache::write_row`] /
//! [`PageCache::write_page`]) are written back on eviction and on
//! [`PageCache::flush`]; reads after eviction re-fault the page from the
//! file, which is why values can never depend on eviction order.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::cluster::memory::MemTracker;
use crate::cluster::metrics::StorageCounters;
use crate::coordinator::SimFs;
use crate::Result;

use super::pagefile::PageFile;

/// Handle to a file registered in a [`PageCache`] (index into its table;
/// stable for the cache's lifetime).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FileId(pub u32);

/// One decoded page resident in the cache.
#[derive(Debug)]
struct Frame {
    file: u32,
    page: u32,
    /// Logical access clock stamp (LRU key).
    stamp: u64,
    dirty: bool,
    bytes: u64,
    data: Vec<f32>,
}

/// A byte-budgeted cache of decoded pages over owned [`PageFile`]s.
#[derive(Debug)]
pub struct PageCache {
    /// Byte budget (0 = unbounded).
    budget: u64,
    files: Vec<Option<PageFile>>,
    frames: Vec<Option<Frame>>,
    free_slots: Vec<usize>,
    map: HashMap<(u32, u32), usize>,
    /// LRU index: access stamp → frame slot (stamps are unique ticks, so
    /// `pop_first` yields the deterministic minimum-stamp victim in
    /// O(log n) instead of a full frame scan per eviction).
    lru: BTreeMap<u64, usize>,
    tick: u64,
    used: u64,
    /// Pending simulated I/O seconds (drained by `take_io_secs`).
    io_pending: f64,
    /// Resident bytes last mirrored into a `MemTracker` (see `sync_mem`).
    mem_synced: u64,
    stats: StorageCounters,
}

impl PageCache {
    /// A cache with the given byte budget (`0` = unbounded).
    pub fn new(budget_bytes: u64) -> PageCache {
        PageCache {
            budget: budget_bytes,
            files: Vec::new(),
            frames: Vec::new(),
            free_slots: Vec::new(),
            map: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            used: 0,
            io_pending: 0.0,
            mem_synced: 0,
            stats: StorageCounters { budget_bytes, ..StorageCounters::default() },
        }
    }

    /// The configured byte budget (0 = unbounded).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Currently resident bytes.
    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    /// High-water mark of resident bytes since the last `take_stats`.
    pub fn peak_used(&self) -> u64 {
        self.stats.peak_resident_bytes
    }

    /// Storage counters accumulated so far.
    pub fn stats(&self) -> &StorageCounters {
        &self.stats
    }

    /// Clone-and-reset the counters (peak resets to the current residency)
    /// — used when a scope's counters are absorbed into machine metrics.
    pub fn take_stats(&mut self) -> StorageCounters {
        let out = self.stats.clone();
        self.stats = StorageCounters {
            budget_bytes: self.budget,
            peak_resident_bytes: self.used,
            ..StorageCounters::default()
        };
        out
    }

    /// Drain the pending simulated I/O seconds. Every multi-operation
    /// helper drains before releasing the cache lock, so each thread
    /// charges exactly its own I/O to its own simulated clock.
    pub fn take_io_secs(&mut self) -> f64 {
        std::mem::take(&mut self.io_pending)
    }

    /// Mirror the resident-byte delta since the last sync into `mem`.
    /// Single-writer by contract: only the rank's main thread syncs (the
    /// server thread shares the cache but never the tracker).
    pub fn sync_mem(&mut self, mem: &mut MemTracker) {
        if self.used >= self.mem_synced {
            mem.alloc(self.used - self.mem_synced);
        } else {
            mem.free(self.mem_synced - self.used);
        }
        self.mem_synced = self.used;
    }

    /// Register a new zero-filled page file owned by this cache.
    pub fn create_file(
        &mut self,
        tag: &str,
        rows: usize,
        cols: usize,
        page_rows: usize,
        fs: Arc<SimFs>,
    ) -> Result<FileId> {
        let pf = PageFile::create(tag, rows, cols, page_rows, fs)?;
        self.files.push(Some(pf));
        Ok(FileId(self.files.len() as u32 - 1))
    }

    /// Shape of a registered file.
    pub fn file_shape(&self, f: FileId) -> (usize, usize, usize) {
        let pf = self.files[f.0 as usize].as_ref().expect("file removed");
        (pf.rows, pf.cols, pf.page_rows)
    }

    /// Drop a file and every frame it has resident (no write-back — the
    /// contents are dead). The id is retired, not reused.
    pub fn remove_file(&mut self, f: FileId) {
        for slot in 0..self.frames.len() {
            let matches = self.frames[slot]
                .as_ref()
                .is_some_and(|fr| fr.file == f.0);
            if matches {
                let fr = self.frames[slot].take().unwrap();
                self.used -= fr.bytes;
                self.map.remove(&(fr.file, fr.page));
                self.lru.remove(&fr.stamp);
                self.free_slots.push(slot);
            }
        }
        self.files[f.0 as usize] = None; // Drop deletes the temp file
    }

    /// Drop every resident frame without write-back (scope teardown).
    pub fn drop_all_frames(&mut self) {
        for slot in 0..self.frames.len() {
            if let Some(fr) = self.frames[slot].take() {
                self.used -= fr.bytes;
                self.map.remove(&(fr.file, fr.page));
                self.free_slots.push(slot);
            }
        }
        self.lru.clear();
        debug_assert_eq!(self.used, 0);
    }

    /// Evict least-recently-stamped frames until `incoming` more bytes fit
    /// under the budget (or nothing is left to evict).
    fn ensure_room(&mut self, incoming: u64) -> Result<()> {
        if self.budget == 0 {
            return Ok(());
        }
        while self.used + incoming > self.budget && !self.map.is_empty() {
            // deterministic LRU victim: minimum logical-clock stamp
            let (_, victim) = self
                .lru
                .pop_first()
                .expect("map non-empty implies an LRU entry exists");
            let fr = self.frames[victim].take().unwrap();
            if fr.dirty {
                let pf = self.files[fr.file as usize]
                    .as_mut()
                    .expect("file removed with live dirty frame");
                self.io_pending += pf.write_page(fr.page as usize, &fr.data)?;
                self.stats.spill_bytes_written += fr.bytes;
            }
            self.used -= fr.bytes;
            self.map.remove(&(fr.file, fr.page));
            self.free_slots.push(victim);
            self.stats.evictions += 1;
        }
        Ok(())
    }

    /// Locate (or fault in) the frame for `(f, page)` and return its slot.
    /// `load` = read the page from disk on a miss (false = the caller
    /// overwrites the whole page, so a zero frame suffices and no fault
    /// is counted).
    fn frame_slot(&mut self, f: FileId, page: usize, load: bool) -> Result<usize> {
        let key = (f.0, page as u32);
        self.tick += 1;
        if let Some(&slot) = self.map.get(&key) {
            let fr = self.frames[slot].as_mut().expect("mapped frame");
            self.lru.remove(&fr.stamp);
            fr.stamp = self.tick;
            self.lru.insert(self.tick, slot);
            return Ok(slot);
        }
        let bytes = {
            let pf = self.files[f.0 as usize].as_ref().expect("file removed");
            pf.page_nbytes(page)
        };
        self.ensure_room(bytes)?;
        let mut data = Vec::new();
        if load {
            let pf = self.files[f.0 as usize].as_mut().expect("file removed");
            self.io_pending += pf.read_page(page, &mut data)?;
            self.stats.page_faults += 1;
            self.stats.spill_bytes_read += bytes;
        } else {
            let pf = self.files[f.0 as usize].as_ref().expect("file removed");
            data = vec![0.0; pf.page_len(page)];
        }
        let stamp = self.tick;
        let frame = Frame { file: f.0, page: page as u32, stamp, dirty: false, bytes, data };
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.frames[s] = Some(frame);
                s
            }
            None => {
                self.frames.push(Some(frame));
                self.frames.len() - 1
            }
        };
        self.map.insert(key, slot);
        self.lru.insert(stamp, slot);
        self.used += bytes;
        if self.used > self.stats.peak_resident_bytes {
            self.stats.peak_resident_bytes = self.used;
        }
        Ok(slot)
    }

    /// Read page `p` of file `f` (faulting it in if absent).
    pub fn read_page(&mut self, f: FileId, p: usize) -> Result<&[f32]> {
        let slot = self.frame_slot(f, p, true)?;
        Ok(&self.frames[slot].as_ref().unwrap().data)
    }

    /// Read row `r` of file `f` through the cache.
    pub fn read_row(&mut self, f: FileId, r: usize) -> Result<&[f32]> {
        let (rows, cols, page_rows) = self.file_shape(f);
        anyhow::ensure!(r < rows, "row {} out of {} rows", r, rows);
        let page = r / page_rows;
        let slot = self.frame_slot(f, page, true)?;
        let off = (r - page * page_rows) * cols;
        Ok(&self.frames[slot].as_ref().unwrap().data[off..off + cols])
    }

    /// Copy row `r` of file `f` into `out` (`out.len() == cols`).
    pub fn copy_row(&mut self, f: FileId, r: usize, out: &mut [f32]) -> Result<()> {
        let row = self.read_row(f, r)?;
        anyhow::ensure!(out.len() == row.len(), "row width {} != buffer {}", row.len(), out.len());
        out.copy_from_slice(row);
        Ok(())
    }

    /// Write row `r` of file `f` through the cache (read-modify-write;
    /// the page is marked dirty and written back on eviction or flush).
    pub fn write_row(&mut self, f: FileId, r: usize, row: &[f32]) -> Result<()> {
        let (rows, cols, page_rows) = self.file_shape(f);
        anyhow::ensure!(r < rows, "row {} out of {} rows", r, rows);
        anyhow::ensure!(row.len() == cols, "row width {} != {} cols", row.len(), cols);
        let page = r / page_rows;
        let slot = self.frame_slot(f, page, true)?;
        let fr = self.frames[slot].as_mut().unwrap();
        let off = (r - page * page_rows) * cols;
        fr.data[off..off + cols].copy_from_slice(row);
        fr.dirty = true;
        Ok(())
    }

    /// Overwrite the whole page `p` of file `f` (no fault — the prior
    /// contents are irrelevant). The staging fast path for sequential
    /// builds: `PagedMatrix::from_matrix` and band writers use this.
    pub fn write_page(&mut self, f: FileId, p: usize, data: &[f32]) -> Result<()> {
        {
            let pf = self.files[f.0 as usize].as_ref().expect("file removed");
            anyhow::ensure!(
                data.len() == pf.page_len(p),
                "page {} holds {} elements, got {}",
                p,
                pf.page_len(p),
                data.len()
            );
        }
        let slot = self.frame_slot(f, p, false)?;
        let fr = self.frames[slot].as_mut().unwrap();
        fr.data.clear();
        fr.data.extend_from_slice(data);
        fr.dirty = true;
        Ok(())
    }

    /// Write every dirty frame back to its file.
    pub fn flush(&mut self) -> Result<()> {
        for slot in 0..self.frames.len() {
            let needs = self.frames[slot].as_ref().is_some_and(|fr| fr.dirty);
            if !needs {
                continue;
            }
            let fr = self.frames[slot].as_mut().unwrap();
            let pf = self.files[fr.file as usize]
                .as_mut()
                .expect("file removed with live dirty frame");
            self.io_pending += pf.write_page(fr.page as usize, &fr.data)?;
            self.stats.spill_bytes_written += fr.bytes;
            fr.dirty = false;
        }
        for pf in self.files.iter_mut().flatten() {
            pf.sync()?;
        }
        Ok(())
    }
}

/// A [`PageCache`] behind a mutex, shared between a machine's main thread
/// and its feature-server thread (and, in the serving tier, pool
/// workers). Every helper drains its own simulated I/O before releasing
/// the lock, so clock attribution stays per-thread.
#[derive(Clone)]
pub struct SharedPageCache {
    inner: Arc<Mutex<PageCache>>,
}

impl SharedPageCache {
    /// A shared cache with the given byte budget (`0` = unbounded).
    pub fn new(budget_bytes: u64) -> SharedPageCache {
        SharedPageCache { inner: Arc::new(Mutex::new(PageCache::new(budget_bytes))) }
    }

    /// Run `f` with the cache locked.
    pub fn with<R>(&self, f: impl FnOnce(&mut PageCache) -> R) -> R {
        f(&mut self.inner.lock().unwrap())
    }
}

impl std::fmt::Debug for SharedPageCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (budget, used) = self.with(|c| (c.budget(), c.used_bytes()));
        write!(f, "SharedPageCache {{ budget: {}, used: {} }}", budget, used)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs() -> Arc<SimFs> {
        SimFs::new(crate::storage::DEFAULT_SPILL_GBPS)
    }

    fn filled(cache: &mut PageCache, rows: usize, cols: usize, page_rows: usize) -> FileId {
        let f = cache.create_file("t", rows, cols, page_rows, fs()).unwrap();
        for r in 0..rows {
            let row: Vec<f32> = (0..cols).map(|c| (r * cols + c) as f32).collect();
            cache.write_row(f, r, &row).unwrap();
        }
        f
    }

    #[test]
    fn rows_read_back_through_evictions() {
        // budget of exactly two 2-row pages over an 8-row file
        let page_bytes = 2 * 3 * 4;
        let mut cache = PageCache::new(2 * page_bytes);
        let f = filled(&mut cache, 8, 3, 2);
        cache.flush().unwrap();
        assert!(cache.used_bytes() <= 2 * page_bytes);
        for r in (0..8).rev() {
            let row = cache.read_row(f, r).unwrap().to_vec();
            let expect: Vec<f32> = (0..3).map(|c| (r * 3 + c) as f32).collect();
            assert_eq!(row, expect, "row {} after eviction churn", r);
        }
        assert!(cache.stats().evictions > 0, "tiny budget must evict");
        assert!(cache.stats().page_faults > 0);
        assert!(cache.peak_used() <= 2 * page_bytes, "evict-before-load bounds residency");
        assert!(cache.take_io_secs() > 0.0);
    }

    #[test]
    fn dirty_pages_write_back_on_eviction() {
        let page_bytes = 2 * 2 * 4;
        let mut cache = PageCache::new(page_bytes); // one page resident
        let f = cache.create_file("wb", 4, 2, 2, fs()).unwrap();
        cache.write_row(f, 0, &[1.0, 2.0]).unwrap();
        cache.write_row(f, 3, &[7.0, 8.0]).unwrap(); // evicts dirty page 0
        assert!(cache.stats().spill_bytes_written >= page_bytes);
        assert_eq!(cache.read_row(f, 0).unwrap(), &[1.0, 2.0], "written-back row survives");
        cache.flush().unwrap();
        assert_eq!(cache.read_row(f, 3).unwrap(), &[7.0, 8.0]);
    }

    #[test]
    fn lru_evicts_least_recently_stamped() {
        let page_bytes = 2 * 4; // one-row pages, two f32 cols
        let mut cache = PageCache::new(2 * page_bytes);
        let f = filled(&mut cache, 3, 2, 1);
        cache.flush().unwrap();
        cache.drop_all_frames();
        let faults0 = cache.stats().page_faults;
        let _ = cache.read_row(f, 0).unwrap(); // pages: {0}
        let _ = cache.read_row(f, 1).unwrap(); // {0, 1}
        let _ = cache.read_row(f, 0).unwrap(); // hit, 0 freshened
        let _ = cache.read_row(f, 2).unwrap(); // evicts 1 (LRU), {0, 2}
        let _ = cache.read_row(f, 0).unwrap(); // hit — 0 must still be resident
        assert_eq!(cache.stats().page_faults - faults0, 3, "exactly pages 0, 1, 2 faulted");
        let _ = cache.read_row(f, 1).unwrap(); // refault
        assert_eq!(cache.stats().page_faults - faults0, 4);
    }

    #[test]
    fn unbounded_budget_never_evicts() {
        let mut cache = PageCache::new(0);
        let f = filled(&mut cache, 64, 4, 8);
        for r in 0..64 {
            let _ = cache.read_row(f, r).unwrap();
        }
        assert_eq!(cache.stats().evictions, 0);
        assert_eq!(cache.used_bytes(), 64 * 4 * 4);
    }

    #[test]
    fn mem_sync_mirrors_residency() {
        let mut cache = PageCache::new(0);
        let mut mem = MemTracker::default();
        let f = filled(&mut cache, 4, 2, 2);
        cache.sync_mem(&mut mem);
        assert_eq!(mem.current(), 4 * 2 * 4);
        cache.drop_all_frames();
        cache.sync_mem(&mut mem);
        assert_eq!(mem.current(), 0);
        assert_eq!(mem.underflow_events(), 0);
        let _ = f;
    }

    #[test]
    fn remove_file_frees_frames_and_retires_id() {
        let mut cache = PageCache::new(0);
        let f = filled(&mut cache, 4, 2, 2);
        let g = filled(&mut cache, 2, 2, 2);
        cache.remove_file(f);
        assert_eq!(cache.used_bytes(), 2 * 2 * 4, "only g's frames remain");
        let row = cache.read_row(g, 1).unwrap();
        assert_eq!(row, &[2.0, 3.0]);
    }

    #[test]
    fn take_stats_resets_and_keeps_budget() {
        let mut cache = PageCache::new(1024);
        let f = filled(&mut cache, 4, 2, 2);
        let _ = cache.read_row(f, 0).unwrap();
        let s = cache.take_stats();
        assert_eq!(s.budget_bytes, 1024);
        assert!(s.peak_resident_bytes > 0);
        let s2 = cache.stats();
        assert_eq!(s2.page_faults, 0, "counters reset");
        assert_eq!(s2.budget_bytes, 1024, "budget survives the reset");
    }
}
