//! Distributed GCN forward pass (paper §2.1 workflow, Fig. 1): per layer a
//! distributed GEMM projection followed by the feature-exchange SPMM mean
//! aggregation over the sampled layer graph `G_l`, with a local self-loop
//! contribution and fused bias + ReLU (identity on the last layer).

use crate::cluster::Ctx;
use crate::partition::PartitionPlan;
use crate::primitives::gemm::deal_gemm;
use crate::primitives::spmm::{deal_spmm, EdgeValues, SpmmInput};
use crate::runtime::{Act, Backend};
use crate::tensor::Matrix;
use crate::Result;

use super::{ExecOpts, LayerPart, ModelWeights};

/// One machine's full GCN forward: `h` is the local `H^(0)` tile; `parts`
/// holds this partition's slice of each sampled layer graph. Returns the
/// local tile of the final embeddings.
pub fn gcn_forward(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    parts: &[LayerPart],
    h: Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
    opts: &ExecOpts,
) -> Result<Matrix> {
    let (_, m_idx) = plan.coords_of(ctx.rank);
    let (flo, fhi) = plan.feat_range(m_idx);
    let mut h = h;
    ctx.mem.alloc(h.nbytes()); // register the input tile
    let n_layers = weights.config.layers;
    assert_eq!(parts.len(), n_layers);
    for (l, part) in parts.iter().enumerate() {
        let phase = opts.phase + (l as u32) * 0x10;
        // Projection: H W_l (distributed ring GEMM).
        let hw = deal_gemm(ctx, plan, &h, weights.layer_w(l), backend, phase)?;
        ctx.mem.free(h.nbytes());
        drop(h);
        // Aggregation: mean over sampled in-neighbors…
        let input = SpmmInput {
            plan,
            g: &part.csr,
            vals: EdgeValues::Scalar(&part.mean_w),
            h: &hw,
        };
        let mut agg = deal_spmm(ctx, &input, backend, opts.mode, opts.group_cols, phase + 1);
        // …plus the self-loop term (always local) and fused bias + act.
        let act = if l + 1 == n_layers { Act::None } else { Act::Relu };
        let bias = &weights.layer_b(l)[flo..fhi];
        ctx.compute(|| {
            for r in 0..agg.rows {
                let sw = part.self_w[r];
                let hw_row = hw.row(r);
                let row = agg.row_mut(r);
                for j in 0..row.len() {
                    let v = row[j] + sw * hw_row[j] + bias[j];
                    row[j] = match act {
                        Act::None => v,
                        Act::Relu => v.max(0.0),
                    };
                }
            }
        });
        ctx.mem.free(hw.nbytes());
        h = agg;
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::Csr;
    use crate::model::reference::gcn_reference;
    use crate::model::ModelConfig;
    use crate::primitives::{gather_tiles, scatter, ExecMode};
    use crate::sampling::sample_all_layers;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn distributed_gcn_matches_dense_reference() {
        let el = rmat(7, 900, RmatParams::paper(), 31);
        let g = Csr::from(&el);
        let d = 12;
        let mut rng = Rng::new(9);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 77);
        let cfg = ModelConfig::gcn(2, d);
        let weights = ModelWeights::random(&cfg, 3);
        let expect = gcn_reference(&layers, &h0, &weights);

        for (p, m) in [(2usize, 2usize), (4, 1), (1, 2)] {
            let plan = crate::partition::PartitionPlan::new(g.n_rows, d, p, m);
            let tiles = Arc::new(scatter(&plan, &h0));
            // per-partition layer parts
            let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::new();
            for pi in 0..plan.p {
                let (lo, hi) = plan.node_range(pi);
                parts_by_p.push(
                    layers
                        .layers
                        .iter()
                        .map(|lg| LayerPart::new(lg.slice_rows(lo, hi)))
                        .collect(),
                );
            }
            let parts_by_p = Arc::new(parts_by_p);
            let plan2 = plan.clone();
            let weights2 = Arc::new(weights.clone());
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (outs, _) = cluster
                .run(move |ctx| {
                    let (pi, _) = plan2.coords_of(ctx.rank);
                    let opts = ExecOpts { mode: ExecMode::Pipelined, group_cols: 16, phase: 0x40 };
                    gcn_forward(
                        ctx,
                        &plan2,
                        &parts_by_p[pi],
                        tiles[ctx.rank].clone(),
                        &weights2,
                        &crate::runtime::Native,
                        &opts,
                    )
                    .unwrap()
                })
                .unwrap();
            let got = gather_tiles(&plan, d, &outs);
            assert_close(&got.data, &expect.data, 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("plan ({},{}): {}", p, m, e));
        }
    }
}
