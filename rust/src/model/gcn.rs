//! Distributed GCN forward pass (paper §2.1 workflow, Fig. 1): per layer a
//! distributed GEMM projection followed by the feature-exchange SPMM mean
//! aggregation over the sampled layer graph `G_l`, with a local self-loop
//! contribution and fused bias + ReLU (identity on the last layer).
//!
//! When a storage budget is active (`storage::mem_budget() > 0`), each
//! layer's projected tile `HW_l` is spilled to the rank's paged tier right
//! after the GEMM: the SPMM feature server, the local aggregation, and the
//! self-loop pass all fault rows back through the budgeted cache instead
//! of holding the tile resident. Values are bit-identical to the in-memory
//! path at every budget and page size (DESIGN.md §Out-of-core-storage).

use std::sync::Arc;

use crate::cluster::Ctx;
use crate::coordinator::SimFs;
use crate::graph::{Csr, NodeId};
use crate::partition::PartitionPlan;
use crate::primitives::gemm::deal_gemm;
use crate::primitives::spmm::{deal_spmm, deal_spmm_paged, EdgeValues, PagedSpmmInput, SpmmInput};
use crate::runtime::{Act, Backend};
use crate::storage::{self, PagedMatrix, SharedPageCache};
use crate::tensor::Matrix;
use crate::Result;

use super::{reference, ExecOpts, GnnModel, LayerPart, ModelKind, ModelWeights};

/// Model-zoo entry for GCN (see [`crate::model::GnnModel`]).
pub struct GcnModel;

impl GnnModel for GcnModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Gcn
    }

    fn layer(&self, g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
        reference::gcn_layer(g, h, weights, l, relu)
    }

    fn layer_rows(
        &self,
        g: &Csr,
        row_base: usize,
        h: &Matrix,
        weights: &ModelWeights,
        l: usize,
        relu: bool,
        rows: &[NodeId],
    ) -> Matrix {
        reference::gcn_layer_rows(g, row_base, h, weights, l, relu, rows)
    }

    fn forward(
        &self,
        ctx: &mut Ctx,
        plan: &PartitionPlan,
        parts: &[LayerPart],
        h: Matrix,
        weights: &ModelWeights,
        backend: &dyn Backend,
        opts: &ExecOpts,
    ) -> Result<Matrix> {
        gcn_forward(ctx, plan, parts, h, weights, backend, opts)
    }
}

/// Per-rank paged-tier scope for a forward pass: one budgeted cache and
/// one simulated spill device (NVMe-class, per machine), opened only when
/// the ambient storage budget is non-zero.
pub(crate) struct StorageScope {
    pub cache: SharedPageCache,
    pub fs: Arc<SimFs>,
    pub page_rows: usize,
}

impl StorageScope {
    /// Open a scope when the ambient budget knob is active.
    pub fn open() -> Option<StorageScope> {
        let budget = storage::mem_budget();
        (budget > 0).then(|| StorageScope {
            cache: SharedPageCache::new(budget),
            fs: SimFs::new(storage::DEFAULT_SPILL_GBPS),
            page_rows: storage::page_rows(),
        })
    }

    /// Spill `m` into the scope's paged tier, charging staging I/O and
    /// mirroring residency into the rank tracker.
    pub fn spill(&self, ctx: &mut Ctx, tag: &str, m: &Matrix) -> Result<PagedMatrix> {
        let pm = self
            .cache
            .with(|c| PagedMatrix::from_matrix(c, tag, m, self.page_rows, Arc::clone(&self.fs)))?;
        storage::charge_main(ctx, &self.cache);
        Ok(pm)
    }

    /// Drop a spilled tile's file and frames (end of its layer).
    pub fn release(&self, ctx: &mut Ctx, pm: &PagedMatrix) {
        self.cache.with(|c| c.remove_file(pm.file));
        storage::charge_main(ctx, &self.cache);
    }

    /// Close the scope: absorb counters into the machine's metrics.
    pub fn finish(&self, ctx: &mut Ctx) {
        storage::absorb_scope(ctx, &self.cache);
    }
}

/// One machine's full GCN forward: `h` is the local `H^(0)` tile; `parts`
/// holds this partition's slice of each sampled layer graph. Returns the
/// local tile of the final embeddings.
pub fn gcn_forward(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    parts: &[LayerPart],
    h: Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
    opts: &ExecOpts,
) -> Result<Matrix> {
    let (_, m_idx) = plan.coords_of(ctx.rank);
    let (flo, fhi) = plan.feat_range(m_idx);
    let storage_scope = StorageScope::open();
    let mut h = h;
    ctx.mem.alloc(h.nbytes()); // register the input tile
    let n_layers = weights.config.layers;
    assert_eq!(parts.len(), n_layers);
    for (l, part) in parts.iter().enumerate() {
        let phase = opts.phase + (l as u32) * 0x10;
        // Per-layer autotune override (DESIGN.md §Autotuning): when a plan
        // is installed, its choice for this layer replaces the fixed
        // `ExecOpts` mode/tile and pins the chunk granularity for the
        // layer's transfers. All variants are bit-identical — only the
        // simulated schedule changes. (On the fused path the rest-layers
        // re-index from 0; all layers share dims, so the clamped lookup
        // stays representative.)
        let choice = crate::runtime::autotune::layer_choice(l);
        let _chunk_guard = choice.map(|c| crate::cluster::net::ChunkRowsGuard::pin(c.chunk_rows));
        let (mode, group_cols) =
            choice.map_or((opts.mode, opts.group_cols), |c| (c.mode, c.group_cols));
        // Projection: H W_l (distributed ring GEMM).
        let hw = deal_gemm(ctx, plan, &h, weights.layer_w(l), backend, phase)?;
        ctx.mem.free(h.nbytes());
        drop(h);
        let act = if l + 1 == n_layers { Act::None } else { Act::Relu };
        let bias = &weights.layer_b(l)[flo..fhi];
        // One definition of the self-loop + bias + act epilogue; the two
        // arms differ only in where `hw_row` is read from (resident tile
        // vs faulted band) — the shared kernel keeps them bit-identical.
        let epilogue = |r: usize, hw_row: &[f32], row: &mut [f32]| {
            let sw = part.self_w[r];
            for j in 0..row.len() {
                let v = row[j] + sw * hw_row[j] + bias[j];
                row[j] = match act {
                    Act::None => v,
                    Act::Relu => v.max(0.0),
                };
            }
        };
        let mut agg;
        match &storage_scope {
            None => {
                // Aggregation: mean over sampled in-neighbors…
                let input = SpmmInput {
                    plan,
                    g: &part.csr,
                    vals: EdgeValues::Scalar(&part.mean_w),
                    h: &hw,
                };
                agg = deal_spmm(ctx, &input, backend, mode, group_cols, phase + 1);
                // …plus the self-loop term (always local) and fused bias + act.
                ctx.compute(|| {
                    for r in 0..agg.rows {
                        epilogue(r, hw.row(r), agg.row_mut(r));
                    }
                });
                ctx.mem.free(hw.nbytes());
            }
            Some(scope) => {
                // Out-of-core: the projected tile moves to the paged tier
                // and its RAM copy is dropped before the aggregation.
                let pm = scope.spill(ctx, &format!("gcn-hw-r{}-l{}", ctx.rank, l), &hw)?;
                ctx.mem.free(hw.nbytes());
                drop(hw);
                let input = PagedSpmmInput {
                    plan,
                    g: &part.csr,
                    vals: EdgeValues::Scalar(&part.mean_w),
                    h: &pm,
                    cache: &scope.cache,
                };
                agg = deal_spmm_paged(ctx, &input, backend, mode, group_cols, phase + 1)?;
                // Self-loop + bias + act from faulted bands: same rows,
                // same arithmetic order → bit-identical.
                let mut io_total = 0.0f64;
                let mut r0 = 0usize;
                while r0 < agg.rows {
                    let r1 = (r0 + scope.page_rows).min(agg.rows);
                    let (band, io) = pm.band_shared(&scope.cache, r0, r1)?;
                    io_total += io;
                    ctx.compute(|| {
                        for r in r0..r1 {
                            epilogue(r, band.row(r - r0), agg.row_mut(r));
                        }
                    });
                    r0 = r1;
                }
                ctx.advance(io_total);
                scope.release(ctx, &pm);
            }
        }
        h = agg;
    }
    if let Some(scope) = &storage_scope {
        scope.finish(ctx);
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::Csr;
    use crate::model::reference::gcn_reference;
    use crate::model::ModelConfig;
    use crate::primitives::{gather_tiles, scatter, ExecMode};
    use crate::sampling::sample_all_layers;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn distributed_gcn_matches_dense_reference() {
        let el = rmat(7, 900, RmatParams::paper(), 31);
        let g = Csr::from(&el);
        let d = 12;
        let mut rng = Rng::new(9);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 77);
        let cfg = ModelConfig::gcn(2, d);
        let weights = ModelWeights::random(&cfg, 3);
        let expect = gcn_reference(&layers, &h0, &weights);

        for (p, m) in [(2usize, 2usize), (4, 1), (1, 2)] {
            let plan = crate::partition::PartitionPlan::new(g.n_rows, d, p, m);
            let tiles = Arc::new(scatter(&plan, &h0));
            // per-partition layer parts
            let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::new();
            for pi in 0..plan.p {
                let (lo, hi) = plan.node_range(pi);
                parts_by_p.push(
                    layers
                        .layers
                        .iter()
                        .map(|lg| LayerPart::new(lg.slice_rows(lo, hi)))
                        .collect(),
                );
            }
            let parts_by_p = Arc::new(parts_by_p);
            let plan2 = plan.clone();
            let weights2 = Arc::new(weights.clone());
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (outs, _) = cluster
                .run(move |ctx| {
                    let (pi, _) = plan2.coords_of(ctx.rank);
                    let opts = ExecOpts { mode: ExecMode::Pipelined, group_cols: 16, phase: 0x40 };
                    gcn_forward(
                        ctx,
                        &plan2,
                        &parts_by_p[pi],
                        tiles[ctx.rank].clone(),
                        &weights2,
                        &crate::runtime::Native,
                        &opts,
                    )
                    .unwrap()
                })
                .unwrap();
            let got = gather_tiles(&plan, d, &outs);
            assert_close(&got.data, &expect.data, 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("plan ({},{}): {}", p, m, e));
        }
    }

    #[test]
    fn paged_gcn_bit_identical_to_ram() {
        let el = rmat(7, 900, RmatParams::paper(), 31);
        let g = Csr::from(&el);
        let d = 12;
        let mut rng = Rng::new(9);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 77);
        let cfg = ModelConfig::gcn(2, d);
        let weights = Arc::new(ModelWeights::random(&cfg, 3));

        let run = |p: usize, m: usize| -> Matrix {
            let plan = crate::partition::PartitionPlan::new(g.n_rows, d, p, m);
            let tiles = Arc::new(scatter(&plan, &h0));
            let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::new();
            for pi in 0..plan.p {
                let (lo, hi) = plan.node_range(pi);
                parts_by_p.push(
                    layers.layers.iter().map(|lg| LayerPart::new(lg.slice_rows(lo, hi))).collect(),
                );
            }
            let parts_by_p = Arc::new(parts_by_p);
            let plan2 = plan.clone();
            let weights2 = Arc::clone(&weights);
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (outs, _) = cluster
                .run(move |ctx| {
                    let (pi, _) = plan2.coords_of(ctx.rank);
                    let opts = ExecOpts { mode: ExecMode::Pipelined, group_cols: 16, phase: 0x40 };
                    gcn_forward(
                        ctx,
                        &plan2,
                        &parts_by_p[pi],
                        tiles[ctx.rank].clone(),
                        &weights2,
                        &crate::runtime::Native,
                        &opts,
                    )
                    .unwrap()
                })
                .unwrap();
            gather_tiles(&plan, d, &outs)
        };

        for (p, m) in [(2usize, 2usize), (1, 2)] {
            let ram = crate::storage::with_mem_budget(0, || run(p, m));
            for (budget, page_rows) in [(4096u64, 16usize), (1024, 1), (1 << 20, 4096)] {
                let paged = crate::storage::with_mem_budget(budget, || {
                    crate::storage::with_page_rows(page_rows, || run(p, m))
                });
                assert_eq!(
                    paged, ram,
                    "plan ({},{}) budget {} page_rows {}",
                    p, m, budget, page_rows
                );
            }
        }
    }
}
