//! Single-machine dense oracles for the GCN, GAT, and GraphSAGE forward
//! passes — the ground truth the distributed implementations must
//! reproduce bit-for-bit up to float-accumulation order.
//!
//! The per-layer functions ([`gcn_layer`], [`gat_layer`], [`sage_layer`])
//! are exposed separately so the delta-inference state
//! (`coordinator::delta`) can cache every intermediate `H^(l)`; the
//! `*_layer_rows` variants recompute just a set of destination rows — the
//! frontier-restricted recompute behind `GnnModel::layer_rows` — with
//! arithmetic identical to the full layer (projection, attention, and
//! pooling are all row-independent, and `Matrix::matmul` computes each
//! output row independently of the band layout).

use crate::graph::{Csr, NodeId};
use crate::sampling::LayerGraphs;
use crate::tensor::{leaky_relu, Matrix};

use super::{Aggregator, ModelKind, ModelWeights};

/// One dense GCN layer over sampled graph `g`: mean aggregation with a
/// self loop, bias, and optional ReLU.
pub fn gcn_layer(g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
    let hw = h.matmul(weights.layer_w(l));
    let b = weights.layer_b(l);
    let mut out = Matrix::zeros(h.rows, hw.cols);
    for r in 0..g.n_rows {
        let row_nodes = g.row(r);
        let w = 1.0 / (row_nodes.len() as f32 + 1.0);
        let orow = out.row_mut(r);
        for &s in row_nodes {
            for (o, &x) in orow.iter_mut().zip(hw.row(s as usize)) {
                *o += w * x;
            }
        }
        // self loop
        for (o, &x) in orow.iter_mut().zip(hw.row(r)) {
            *o += w * x;
        }
        for (j, o) in orow.iter_mut().enumerate() {
            *o += b[j];
            if relu {
                *o = o.max(0.0);
            }
        }
    }
    out
}

/// Recompute only the destination rows in `rows` (global ids) of
/// [`gcn_layer`] against a partition-local CSR `g` whose local row `i` is
/// global row `row_base + i`. Output row `i` is bit-identical to row
/// `rows[i]` of the full dense layer: the projection is restricted to the
/// gathered rows (`matmul` rows are band-independent) and the
/// accumulation replays the full layer's exact op order.
pub fn gcn_layer_rows(
    g: &Csr,
    row_base: usize,
    h: &Matrix,
    weights: &ModelWeights,
    l: usize,
    relu: bool,
    rows: &[NodeId],
) -> Matrix {
    let mut needed: Vec<usize> = Vec::new();
    for &r in rows {
        needed.push(r as usize);
        needed.extend(g.row(r as usize - row_base).iter().map(|&s| s as usize));
    }
    needed.sort_unstable();
    needed.dedup();
    let sub = h.gather_rows(&needed);
    let hw = sub.matmul(weights.layer_w(l));
    let b = weights.layer_b(l);
    let at = |global: usize| -> usize {
        needed.binary_search(&global).expect("source missing from gather")
    };
    let mut out = Matrix::zeros(rows.len(), hw.cols);
    for (i, &r) in rows.iter().enumerate() {
        let row_nodes = g.row(r as usize - row_base);
        let w = 1.0 / (row_nodes.len() as f32 + 1.0);
        let orow = out.row_mut(i);
        for &s in row_nodes {
            for (o, &x) in orow.iter_mut().zip(hw.row(at(s as usize))) {
                *o += w * x;
            }
        }
        // self loop
        for (o, &x) in orow.iter_mut().zip(hw.row(at(r as usize))) {
            *o += w * x;
        }
        for (j, o) in orow.iter_mut().enumerate() {
            *o += b[j];
            if relu {
                *o = o.max(0.0);
            }
        }
    }
    out
}

/// Dense GCN forward over the sampled layer graphs.
pub fn gcn_reference(layers: &LayerGraphs, h0: &Matrix, weights: &ModelWeights) -> Matrix {
    assert_eq!(weights.config.kind, ModelKind::Gcn);
    let n_layers = weights.config.layers;
    assert_eq!(layers.k(), n_layers);
    let mut h = h0.clone();
    for l in 0..n_layers {
        h = gcn_layer(&layers.layers[l], &h, weights, l, l + 1 != n_layers);
    }
    h
}

/// One dense GAT layer (additive attention, LeakyReLU(0.2), self-loop in
/// the softmax, bias, optional ReLU).
pub fn gat_layer(g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
    let heads = weights.config.heads;
    let z = h.matmul(weights.layer_w(l));
    let d = z.cols;
    let head_dim = d / heads;
    let u = z.matmul(weights.layer_a_dst(l)); // n × heads
    let v = z.matmul(weights.layer_a_src(l)); // n × heads
    let b = weights.layer_b(l);
    let mut out = Matrix::zeros(h.rows, d);
    for r in 0..g.n_rows {
        let nbrs = g.row(r);
        gat_row(
            nbrs,
            r,
            |i| z.row(i),
            |i, hh| u.get(i, hh),
            |i, hh| v.get(i, hh),
            heads,
            head_dim,
            b,
            relu,
            out.row_mut(r),
        );
    }
    out
}

/// Recompute only the destination rows in `rows` (global ids) of
/// [`gat_layer`] against a partition-local CSR `g` whose local row `i` is
/// global row `row_base + i`, projecting just the sources those rows
/// reference. Output row `i` equals row `rows[i]` of the full layer
/// (projection and attention scalars are row-independent, so restricting
/// them changes no arithmetic). Pass `row_base = 0` for a global CSR.
pub fn gat_layer_rows(
    g: &Csr,
    row_base: usize,
    h: &Matrix,
    weights: &ModelWeights,
    l: usize,
    relu: bool,
    rows: &[NodeId],
) -> Matrix {
    let heads = weights.config.heads;
    // Distinct sources the requested rows touch (self loops included).
    let mut needed: Vec<usize> = Vec::new();
    for &r in rows {
        needed.push(r as usize);
        needed.extend(g.row(r as usize - row_base).iter().map(|&s| s as usize));
    }
    needed.sort_unstable();
    needed.dedup();
    let sub = h.gather_rows(&needed);
    let z = sub.matmul(weights.layer_w(l));
    let d = z.cols;
    let head_dim = d / heads;
    let u = z.matmul(weights.layer_a_dst(l));
    let v = z.matmul(weights.layer_a_src(l));
    let b = weights.layer_b(l);
    let at = |global: usize| -> usize {
        needed.binary_search(&global).expect("source missing from gather")
    };
    let mut out = Matrix::zeros(rows.len(), d);
    for (i, &r) in rows.iter().enumerate() {
        let nbrs = g.row(r as usize - row_base);
        gat_row(
            nbrs,
            r as usize,
            |gid| z.row(at(gid)),
            |gid, hh| u.get(at(gid), hh),
            |gid, hh| v.get(at(gid), hh),
            heads,
            head_dim,
            b,
            relu,
            out.row_mut(i),
        );
    }
    out
}

/// Shared per-destination GAT arithmetic: score neighbors + self, softmax
/// per head, aggregate, bias, activation. `z_of`/`u_of`/`v_of` resolve a
/// *global* node id to its projected row / attention scalars.
#[allow(clippy::too_many_arguments)]
fn gat_row<'a>(
    nbrs: &[NodeId],
    r: usize,
    z_of: impl Fn(usize) -> &'a [f32],
    u_of: impl Fn(usize, usize) -> f32,
    v_of: impl Fn(usize, usize) -> f32,
    heads: usize,
    head_dim: usize,
    b: &[f32],
    relu: bool,
    orow: &mut [f32],
) {
    // raw scores per head: neighbors then self
    let mut scores = vec![0.0f32; (nbrs.len() + 1) * heads];
    for (i, &s) in nbrs.iter().enumerate() {
        for hh in 0..heads {
            scores[i * heads + hh] = leaky_relu(u_of(r, hh) + v_of(s as usize, hh));
        }
    }
    for hh in 0..heads {
        scores[nbrs.len() * heads + hh] = leaky_relu(u_of(r, hh) + v_of(r, hh));
    }
    // softmax per head
    let mut alpha = scores.clone();
    for hh in 0..heads {
        let mut mx = f32::NEG_INFINITY;
        for i in 0..=nbrs.len() {
            mx = mx.max(scores[i * heads + hh]);
        }
        let mut sum = 0.0;
        for i in 0..=nbrs.len() {
            let e = (scores[i * heads + hh] - mx).exp();
            alpha[i * heads + hh] = e;
            sum += e;
        }
        for i in 0..=nbrs.len() {
            alpha[i * heads + hh] /= sum;
        }
    }
    // weighted aggregation
    let d = orow.len();
    for (i, &s) in nbrs.iter().enumerate() {
        let zrow = z_of(s as usize);
        for j in 0..d {
            orow[j] += alpha[i * heads + j / head_dim] * zrow[j];
        }
    }
    let zr = z_of(r);
    for j in 0..d {
        orow[j] += alpha[nbrs.len() * heads + j / head_dim] * zr[j];
    }
    for (j, o) in orow.iter_mut().enumerate() {
        *o += b[j];
        if relu {
            *o = o.max(0.0);
        }
    }
}

/// Dense GAT forward over the sampled layer graphs (additive attention,
/// LeakyReLU(0.2), self-loop participates in the softmax, ReLU between
/// layers, none after the last).
pub fn gat_reference(layers: &LayerGraphs, h0: &Matrix, weights: &ModelWeights) -> Matrix {
    assert_eq!(weights.config.kind, ModelKind::Gat);
    let n_layers = weights.config.layers;
    let mut h = h0.clone();
    for l in 0..n_layers {
        h = gat_layer(&layers.layers[l], &h, weights, l, l + 1 != n_layers);
    }
    h
}

/// One dense GraphSAGE layer: mean or max-pool neighbor aggregation plus
/// a separate self projection, bias, optional ReLU. Destinations with no
/// sampled in-neighbors get a zero neighbor term (mean) / zero pooled
/// vector (pool).
pub fn sage_layer(g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
    let hs = h.matmul(weights.layer_w(l));
    let b = weights.layer_b(l);
    let d = hs.cols;
    let mut out = Matrix::zeros(h.rows, d);
    match weights.config.aggregator {
        Aggregator::Mean => {
            let hn = h.matmul(weights.layer_w_neigh(l));
            for r in 0..g.n_rows {
                sage_mean_row(g.row(r), |gid| hn.row(gid), hs.row(r), b, relu, out.row_mut(r));
            }
        }
        Aggregator::Pool => {
            let hp = pooled_rows(h, weights, l);
            let mut mx = Matrix::zeros(h.rows, d);
            for r in 0..g.n_rows {
                pool_max(g.row(r), |gid| hp.row(gid), mx.row_mut(r));
            }
            let mxn = mx.matmul(weights.layer_w_neigh(l));
            for r in 0..g.n_rows {
                sage_pool_row(mxn.row(r), hs.row(r), b, relu, out.row_mut(r));
            }
        }
    }
    out
}

/// Recompute only the destination rows in `rows` (global ids) of
/// [`sage_layer`] against a partition-local CSR `g` whose local row `i`
/// is global row `row_base + i`. Output row `i` is bit-identical to row
/// `rows[i]` of the full layer (projections and the pooling MLP are
/// row-wise, and `f32::max` is exactly order-insensitive).
pub fn sage_layer_rows(
    g: &Csr,
    row_base: usize,
    h: &Matrix,
    weights: &ModelWeights,
    l: usize,
    relu: bool,
    rows: &[NodeId],
) -> Matrix {
    let mut needed: Vec<usize> = Vec::new();
    for &r in rows {
        needed.push(r as usize);
        needed.extend(g.row(r as usize - row_base).iter().map(|&s| s as usize));
    }
    needed.sort_unstable();
    needed.dedup();
    let sub = h.gather_rows(&needed);
    let hs = sub.matmul(weights.layer_w(l));
    let b = weights.layer_b(l);
    let d = hs.cols;
    let at = |global: usize| -> usize {
        needed.binary_search(&global).expect("source missing from gather")
    };
    let mut out = Matrix::zeros(rows.len(), d);
    match weights.config.aggregator {
        Aggregator::Mean => {
            let hn = sub.matmul(weights.layer_w_neigh(l));
            for (i, &r) in rows.iter().enumerate() {
                let nbrs = g.row(r as usize - row_base);
                sage_mean_row(
                    nbrs,
                    |gid| hn.row(at(gid)),
                    hs.row(at(r as usize)),
                    b,
                    relu,
                    out.row_mut(i),
                );
            }
        }
        Aggregator::Pool => {
            let hp = pooled_rows(&sub, weights, l);
            let mut mx = Matrix::zeros(rows.len(), d);
            for (i, &r) in rows.iter().enumerate() {
                pool_max(g.row(r as usize - row_base), |gid| hp.row(at(gid)), mx.row_mut(i));
            }
            let mxn = mx.matmul(weights.layer_w_neigh(l));
            for (i, &r) in rows.iter().enumerate() {
                sage_pool_row(mxn.row(i), hs.row(at(r as usize)), b, relu, out.row_mut(i));
            }
        }
    }
    out
}

/// Shared per-destination SAGE mean arithmetic: `1/deg`-weighted neighbor
/// projections in CSR order, then the self projection, bias, activation.
fn sage_mean_row<'a>(
    nbrs: &[NodeId],
    hn_of: impl Fn(usize) -> &'a [f32],
    self_row: &[f32],
    b: &[f32],
    relu: bool,
    orow: &mut [f32],
) {
    if !nbrs.is_empty() {
        let w = 1.0 / nbrs.len() as f32;
        for &s in nbrs {
            for (o, &x) in orow.iter_mut().zip(hn_of(s as usize)) {
                *o += w * x;
            }
        }
    }
    for (o, &x) in orow.iter_mut().zip(self_row) {
        *o += x;
    }
    for (j, o) in orow.iter_mut().enumerate() {
        *o += b[j];
        if relu {
            *o = o.max(0.0);
        }
    }
}

/// Pooling MLP applied row-wise: `relu(h W_pool + b_pool)`.
fn pooled_rows(h: &Matrix, weights: &ModelWeights, l: usize) -> Matrix {
    let mut hp = h.matmul(weights.layer_w_pool(l));
    let bp = weights.layer_b_pool(l);
    let cols = hp.cols;
    for r in 0..hp.rows {
        let row = hp.row_mut(r);
        for j in 0..cols {
            row[j] = (row[j] + bp[j]).max(0.0);
        }
    }
    hp
}

/// Element-wise max over pooled source rows; empty neighborhoods stay
/// zero (`f32::max` is exactly commutative/associative for non-NaN
/// inputs, so the result is independent of visit order).
fn pool_max<'a>(nbrs: &[NodeId], hp_of: impl Fn(usize) -> &'a [f32], mrow: &mut [f32]) {
    if nbrs.is_empty() {
        return;
    }
    mrow.fill(f32::NEG_INFINITY);
    for &s in nbrs {
        for (m, &x) in mrow.iter_mut().zip(hp_of(s as usize)) {
            *m = m.max(x);
        }
    }
}

/// Combine a pooled-aggregate projection row with the self projection.
fn sage_pool_row(mx_row: &[f32], self_row: &[f32], b: &[f32], relu: bool, orow: &mut [f32]) {
    for (j, o) in orow.iter_mut().enumerate() {
        let v = mx_row[j] + self_row[j] + b[j];
        *o = if relu { v.max(0.0) } else { v };
    }
}

/// Dense GraphSAGE forward over the sampled layer graphs.
pub fn sage_reference(layers: &LayerGraphs, h0: &Matrix, weights: &ModelWeights) -> Matrix {
    assert_eq!(weights.config.kind, ModelKind::Sage);
    let n_layers = weights.config.layers;
    let mut h = h0.clone();
    for l in 0..n_layers {
        h = sage_layer(&layers.layers[l], &h, weights, l, l + 1 != n_layers);
    }
    h
}

/// Classification accuracy of argmax(embeddings) vs labels over a mask.
pub fn accuracy(embeddings: &Matrix, labels: &[u32], mask: impl Fn(usize) -> bool) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for r in 0..embeddings.rows {
        if !mask(r) {
            continue;
        }
        let row = embeddings.row(r);
        let mut best = 0usize;
        for j in 1..row.len() {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[r] as usize {
            correct += 1;
        }
        total += 1;
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::Csr;
    use crate::model::ModelConfig;
    use crate::sampling::sample_all_layers;
    use crate::util::rng::Rng;

    #[test]
    fn gcn_reference_runs_and_is_deterministic() {
        let g = Csr::from(&rmat(6, 300, RmatParams::paper(), 2));
        let layers = sample_all_layers(&g, 2, 3, 1);
        let mut rng = Rng::new(4);
        let h0 = Matrix::random(g.n_rows, 8, 1.0, &mut rng);
        let w = ModelWeights::random(&ModelConfig::gcn(2, 8), 5);
        let a = gcn_reference(&layers, &h0, &w);
        let b = gcn_reference(&layers, &h0, &w);
        assert_eq!(a, b);
        assert_eq!(a.rows, g.n_rows);
    }

    #[test]
    fn gat_alpha_rows_sum_to_one_implicitly() {
        // With all-equal z rows, attention must average: out == z row + b.
        let g = Csr::from_edges(3, &[(1, 0), (2, 0), (0, 1)]);
        let layers = LayerGraphs { layers: vec![g] };
        let d = 4;
        let cfg = ModelConfig::gat(1, d, 2);
        let mut w = ModelWeights::random(&cfg, 6);
        // identity W, zero bias
        w.tensors[0] = {
            let mut m = Matrix::zeros(d, d);
            for i in 0..d {
                m.set(i, i, 1.0);
            }
            m
        };
        w.tensors[1] = Matrix::zeros(1, d);
        let mut h0 = Matrix::zeros(3, d);
        for r in 0..3 {
            for j in 0..d {
                h0.set(r, j, 1.5); // identical rows
            }
        }
        let out = gat_reference(&layers, &h0, &w);
        for v in &out.data {
            assert!((v - 1.5).abs() < 1e-5, "convex combination broken: {}", v);
        }
    }

    #[test]
    fn gat_layer_rows_matches_full_layer() {
        let g = Csr::from(&rmat(6, 400, RmatParams::paper(), 9));
        let cfg = ModelConfig::gat(1, 8, 4);
        let w = ModelWeights::random(&cfg, 11);
        let mut rng = Rng::new(12);
        let h = Matrix::random(g.n_rows, 8, 1.0, &mut rng);
        let full = gat_layer(&g, &h, &w, 0, true);
        let rows: [NodeId; 4] = [0, 5, 17, (g.n_rows - 1) as NodeId];
        let got = gat_layer_rows(&g, 0, &h, &w, 0, true, &rows);
        for (i, &r) in rows.iter().enumerate() {
            // row-independent arithmetic: restriction is bit-exact
            assert_eq!(got.row(i), full.row(r as usize), "row {} diverged", r);
        }
    }

    #[test]
    fn sage_reference_runs_and_zero_degree_rows_get_self_only() {
        // node 2 has no in-edges: its mean output must be h[2]·W_self + b.
        let g = Csr::from_edges(3, &[(1, 0), (2, 0), (0, 1)]);
        let layers = LayerGraphs { layers: vec![g] };
        let cfg = ModelConfig::sage(1, 4, Aggregator::Mean);
        let w = ModelWeights::random(&cfg, 3);
        let mut rng = Rng::new(4);
        let h0 = Matrix::random(3, 4, 1.0, &mut rng);
        let out = sage_reference(&layers, &h0, &w);
        let self_only = h0.gather_rows(&[2]).matmul(w.layer_w(0));
        for j in 0..4 {
            assert_eq!(out.get(2, j), self_only.get(0, j) + w.layer_b(0)[j]);
        }
    }

    #[test]
    fn layer_rows_partition_slice_bit_exact_all_kinds() {
        // The GnnModel::layer_rows contract: against a partition-local CSR
        // slice (local rows, global columns), restricted recompute of any
        // row set is bit-identical to the full dense layer on the global
        // graph — for every model in the zoo.
        let g = Csr::from(&rmat(6, 400, RmatParams::paper(), 9));
        let n = g.n_rows;
        let (lo, hi) = (n / 3, 2 * n / 3);
        let mut edges = Vec::new();
        for r in lo..hi {
            for &s in g.row(r) {
                edges.push((s, (r - lo) as NodeId));
            }
        }
        let slice = Csr::from_edges_rect(hi - lo, n, &edges);
        for r in lo..hi {
            assert_eq!(slice.row(r - lo), g.row(r), "slice must preserve row order");
        }
        let mut rng = Rng::new(12);
        let h = Matrix::random(n, 8, 1.0, &mut rng);
        let configs = [
            ModelConfig::gcn(1, 8),
            ModelConfig::gat(1, 8, 4),
            ModelConfig::sage(1, 8, Aggregator::Mean),
            ModelConfig::sage(1, 8, Aggregator::Pool),
        ];
        for cfg in configs {
            let w = ModelWeights::random(&cfg, 11);
            let model = cfg.kind.model();
            let full = model.layer(&g, &h, &w, 0, true);
            let rows: Vec<NodeId> =
                vec![lo as NodeId, (lo + 3) as NodeId, (hi - 1) as NodeId];
            let got = model.layer_rows(&slice, lo, &h, &w, 0, true, &rows);
            for (i, &r) in rows.iter().enumerate() {
                assert_eq!(
                    got.row(i),
                    full.row(r as usize),
                    "{:?}/{:?} row {} diverged",
                    cfg.kind,
                    cfg.aggregator,
                    r
                );
            }
        }
    }

    #[test]
    fn accuracy_counts() {
        let e = Matrix::from_vec(3, 2, vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        let labels = vec![0, 1, 1];
        let acc = accuracy(&e, &labels, |_| true);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
        let acc_masked = accuracy(&e, &labels, |r| r < 2);
        assert!((acc_masked - 1.0).abs() < 1e-9);
    }
}
