//! Single-machine dense oracles for the GCN and GAT forward passes —
//! the ground truth the distributed implementations must reproduce
//! bit-for-bit up to float-accumulation order.
//!
//! The per-layer functions ([`gcn_layer`], [`gat_layer`]) are exposed
//! separately so the delta-inference state (`coordinator::delta`) can
//! cache every intermediate `H^(l)`; [`gat_layer_rows`] recomputes just a
//! set of destination rows — the affected-set fallback path for GAT —
//! with arithmetic identical to the full layer (projection and attention
//! are row-independent).

use crate::graph::{Csr, NodeId};
use crate::sampling::LayerGraphs;
use crate::tensor::{leaky_relu, Matrix};

use super::{ModelKind, ModelWeights};

/// One dense GCN layer over sampled graph `g`: mean aggregation with a
/// self loop, bias, and optional ReLU.
pub fn gcn_layer(g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
    let hw = h.matmul(weights.layer_w(l));
    let b = weights.layer_b(l);
    let mut out = Matrix::zeros(h.rows, hw.cols);
    for r in 0..g.n_rows {
        let row_nodes = g.row(r);
        let w = 1.0 / (row_nodes.len() as f32 + 1.0);
        let orow = out.row_mut(r);
        for &s in row_nodes {
            for (o, &x) in orow.iter_mut().zip(hw.row(s as usize)) {
                *o += w * x;
            }
        }
        // self loop
        for (o, &x) in orow.iter_mut().zip(hw.row(r)) {
            *o += w * x;
        }
        for (j, o) in orow.iter_mut().enumerate() {
            *o += b[j];
            if relu {
                *o = o.max(0.0);
            }
        }
    }
    out
}

/// Dense GCN forward over the sampled layer graphs.
pub fn gcn_reference(layers: &LayerGraphs, h0: &Matrix, weights: &ModelWeights) -> Matrix {
    assert_eq!(weights.config.kind, ModelKind::Gcn);
    let n_layers = weights.config.layers;
    assert_eq!(layers.k(), n_layers);
    let mut h = h0.clone();
    for l in 0..n_layers {
        h = gcn_layer(&layers.layers[l], &h, weights, l, l + 1 != n_layers);
    }
    h
}

/// One dense GAT layer (additive attention, LeakyReLU(0.2), self-loop in
/// the softmax, bias, optional ReLU).
pub fn gat_layer(g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
    let heads = weights.config.heads;
    let z = h.matmul(weights.layer_w(l));
    let d = z.cols;
    let head_dim = d / heads;
    let u = z.matmul(weights.layer_a_dst(l)); // n × heads
    let v = z.matmul(weights.layer_a_src(l)); // n × heads
    let b = weights.layer_b(l);
    let mut out = Matrix::zeros(h.rows, d);
    for r in 0..g.n_rows {
        let nbrs = g.row(r);
        gat_row(
            nbrs,
            r,
            |i| z.row(i),
            |i, hh| u.get(i, hh),
            |i, hh| v.get(i, hh),
            heads,
            head_dim,
            b,
            relu,
            out.row_mut(r),
        );
    }
    out
}

/// Recompute only the destination rows in `rows` of [`gat_layer`],
/// projecting just the sources those rows reference. Output row `i`
/// equals row `rows[i]` of the full layer (projection and attention
/// scalars are row-independent, so restricting them changes no
/// arithmetic).
pub fn gat_layer_rows(
    g: &Csr,
    h: &Matrix,
    weights: &ModelWeights,
    l: usize,
    relu: bool,
    rows: &[NodeId],
) -> Matrix {
    let heads = weights.config.heads;
    // Distinct sources the requested rows touch (self loops included).
    let mut needed: Vec<usize> = Vec::new();
    for &r in rows {
        needed.push(r as usize);
        needed.extend(g.row(r as usize).iter().map(|&s| s as usize));
    }
    needed.sort_unstable();
    needed.dedup();
    let sub = h.gather_rows(&needed);
    let z = sub.matmul(weights.layer_w(l));
    let d = z.cols;
    let head_dim = d / heads;
    let u = z.matmul(weights.layer_a_dst(l));
    let v = z.matmul(weights.layer_a_src(l));
    let b = weights.layer_b(l);
    let at = |global: usize| -> usize {
        needed.binary_search(&global).expect("source missing from gather")
    };
    let mut out = Matrix::zeros(rows.len(), d);
    for (i, &r) in rows.iter().enumerate() {
        let nbrs = g.row(r as usize);
        gat_row(
            nbrs,
            r as usize,
            |gid| z.row(at(gid)),
            |gid, hh| u.get(at(gid), hh),
            |gid, hh| v.get(at(gid), hh),
            heads,
            head_dim,
            b,
            relu,
            out.row_mut(i),
        );
    }
    out
}

/// Shared per-destination GAT arithmetic: score neighbors + self, softmax
/// per head, aggregate, bias, activation. `z_of`/`u_of`/`v_of` resolve a
/// *global* node id to its projected row / attention scalars.
#[allow(clippy::too_many_arguments)]
fn gat_row<'a>(
    nbrs: &[NodeId],
    r: usize,
    z_of: impl Fn(usize) -> &'a [f32],
    u_of: impl Fn(usize, usize) -> f32,
    v_of: impl Fn(usize, usize) -> f32,
    heads: usize,
    head_dim: usize,
    b: &[f32],
    relu: bool,
    orow: &mut [f32],
) {
    // raw scores per head: neighbors then self
    let mut scores = vec![0.0f32; (nbrs.len() + 1) * heads];
    for (i, &s) in nbrs.iter().enumerate() {
        for hh in 0..heads {
            scores[i * heads + hh] = leaky_relu(u_of(r, hh) + v_of(s as usize, hh));
        }
    }
    for hh in 0..heads {
        scores[nbrs.len() * heads + hh] = leaky_relu(u_of(r, hh) + v_of(r, hh));
    }
    // softmax per head
    let mut alpha = scores.clone();
    for hh in 0..heads {
        let mut mx = f32::NEG_INFINITY;
        for i in 0..=nbrs.len() {
            mx = mx.max(scores[i * heads + hh]);
        }
        let mut sum = 0.0;
        for i in 0..=nbrs.len() {
            let e = (scores[i * heads + hh] - mx).exp();
            alpha[i * heads + hh] = e;
            sum += e;
        }
        for i in 0..=nbrs.len() {
            alpha[i * heads + hh] /= sum;
        }
    }
    // weighted aggregation
    let d = orow.len();
    for (i, &s) in nbrs.iter().enumerate() {
        let zrow = z_of(s as usize);
        for j in 0..d {
            orow[j] += alpha[i * heads + j / head_dim] * zrow[j];
        }
    }
    let zr = z_of(r);
    for j in 0..d {
        orow[j] += alpha[nbrs.len() * heads + j / head_dim] * zr[j];
    }
    for (j, o) in orow.iter_mut().enumerate() {
        *o += b[j];
        if relu {
            *o = o.max(0.0);
        }
    }
}

/// Dense GAT forward over the sampled layer graphs (additive attention,
/// LeakyReLU(0.2), self-loop participates in the softmax, ReLU between
/// layers, none after the last).
pub fn gat_reference(layers: &LayerGraphs, h0: &Matrix, weights: &ModelWeights) -> Matrix {
    assert_eq!(weights.config.kind, ModelKind::Gat);
    let n_layers = weights.config.layers;
    let mut h = h0.clone();
    for l in 0..n_layers {
        h = gat_layer(&layers.layers[l], &h, weights, l, l + 1 != n_layers);
    }
    h
}

/// Classification accuracy of argmax(embeddings) vs labels over a mask.
pub fn accuracy(embeddings: &Matrix, labels: &[u32], mask: impl Fn(usize) -> bool) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for r in 0..embeddings.rows {
        if !mask(r) {
            continue;
        }
        let row = embeddings.row(r);
        let mut best = 0usize;
        for j in 1..row.len() {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[r] as usize {
            correct += 1;
        }
        total += 1;
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::Csr;
    use crate::model::ModelConfig;
    use crate::sampling::sample_all_layers;
    use crate::util::rng::Rng;

    #[test]
    fn gcn_reference_runs_and_is_deterministic() {
        let g = Csr::from(&rmat(6, 300, RmatParams::paper(), 2));
        let layers = sample_all_layers(&g, 2, 3, 1);
        let mut rng = Rng::new(4);
        let h0 = Matrix::random(g.n_rows, 8, 1.0, &mut rng);
        let w = ModelWeights::random(&ModelConfig::gcn(2, 8), 5);
        let a = gcn_reference(&layers, &h0, &w);
        let b = gcn_reference(&layers, &h0, &w);
        assert_eq!(a, b);
        assert_eq!(a.rows, g.n_rows);
    }

    #[test]
    fn gat_alpha_rows_sum_to_one_implicitly() {
        // With all-equal z rows, attention must average: out == z row + b.
        let g = Csr::from_edges(3, &[(1, 0), (2, 0), (0, 1)]);
        let layers = LayerGraphs { layers: vec![g] };
        let d = 4;
        let cfg = ModelConfig::gat(1, d, 2);
        let mut w = ModelWeights::random(&cfg, 6);
        // identity W, zero bias
        w.tensors[0] = {
            let mut m = Matrix::zeros(d, d);
            for i in 0..d {
                m.set(i, i, 1.0);
            }
            m
        };
        w.tensors[1] = Matrix::zeros(1, d);
        let mut h0 = Matrix::zeros(3, d);
        for r in 0..3 {
            for j in 0..d {
                h0.set(r, j, 1.5); // identical rows
            }
        }
        let out = gat_reference(&layers, &h0, &w);
        for v in &out.data {
            assert!((v - 1.5).abs() < 1e-5, "convex combination broken: {}", v);
        }
    }

    #[test]
    fn gat_layer_rows_matches_full_layer() {
        let g = Csr::from(&rmat(6, 400, RmatParams::paper(), 9));
        let cfg = ModelConfig::gat(1, 8, 4);
        let w = ModelWeights::random(&cfg, 11);
        let mut rng = Rng::new(12);
        let h = Matrix::random(g.n_rows, 8, 1.0, &mut rng);
        let full = gat_layer(&g, &h, &w, 0, true);
        let rows: [NodeId; 4] = [0, 5, 17, (g.n_rows - 1) as NodeId];
        let got = gat_layer_rows(&g, &h, &w, 0, true, &rows);
        for (i, &r) in rows.iter().enumerate() {
            // row-independent arithmetic: restriction is bit-exact
            assert_eq!(got.row(i), full.row(r as usize), "row {} diverged", r);
        }
    }

    #[test]
    fn accuracy_counts() {
        let e = Matrix::from_vec(3, 2, vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        let labels = vec![0, 1, 1];
        let acc = accuracy(&e, &labels, |_| true);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
        let acc_masked = accuracy(&e, &labels, |r| r < 2);
        assert!((acc_masked - 1.0).abs() < 1e-9);
    }
}
