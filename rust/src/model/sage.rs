//! Distributed GraphSAGE forward pass.
//!
//! Per layer, SAGE separates the self projection `H W_self` from the
//! neighbor aggregate:
//!
//! - **mean**: `act( mean_s(H[s] W_neigh) + H[r] W_self + b )` — a second
//!   distributed GEMM followed by the feature-exchange SPMM with `1/deg`
//!   edge weights (destinations with no sampled in-neighbors keep a zero
//!   neighbor term). Under an active storage budget the neighbor tile is
//!   spilled to the paged tier exactly like GCN's `HW_l`.
//! - **pool**: `act( max_s relu(H[s] W_pool + b_pool) · W_neigh + H[r]
//!   W_self + b )` — the pooling MLP is applied to the local tile, pooled
//!   rows for remote sources ship over GAT's `fetch_v` exchange (it is
//!   shape-agnostic over columns), the element-wise max is computed
//!   locally per destination (`f32::max` is exactly order-insensitive, so
//!   the result is deterministic regardless of visit order), and the
//!   pooled aggregate goes through one more distributed GEMM.
//!
//! Unlike GAT there is no head-alignment constraint: SAGE runs on any
//! `(P, M)` grid, which keeps the `DEAL_MODEL=sage` CI sweep unrestricted.

use crate::cluster::Ctx;
use crate::graph::{Csr, NodeId};
use crate::partition::PartitionPlan;
use crate::primitives::gemm::deal_gemm;
use crate::primitives::spmm::{deal_spmm, deal_spmm_paged, EdgeValues, PagedSpmmInput, SpmmInput};
use crate::runtime::{Act, Backend};
use crate::tensor::Matrix;
use crate::Result;

use super::gat::fetch_v;
use super::gcn::StorageScope;
use super::{reference, Aggregator, ExecOpts, GnnModel, LayerPart, ModelKind, ModelWeights};

/// Model-zoo entry for GraphSAGE (see [`crate::model::GnnModel`]).
pub struct SageModel;

impl GnnModel for SageModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Sage
    }

    fn layer(&self, g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
        reference::sage_layer(g, h, weights, l, relu)
    }

    fn layer_rows(
        &self,
        g: &Csr,
        row_base: usize,
        h: &Matrix,
        weights: &ModelWeights,
        l: usize,
        relu: bool,
        rows: &[NodeId],
    ) -> Matrix {
        reference::sage_layer_rows(g, row_base, h, weights, l, relu, rows)
    }

    fn forward(
        &self,
        ctx: &mut Ctx,
        plan: &PartitionPlan,
        parts: &[LayerPart],
        h: Matrix,
        weights: &ModelWeights,
        backend: &dyn Backend,
        opts: &ExecOpts,
    ) -> Result<Matrix> {
        sage_forward(ctx, plan, parts, h, weights, backend, opts)
    }
}

/// One machine's full GraphSAGE forward. Same contract as `gcn_forward`.
pub fn sage_forward(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    parts: &[LayerPart],
    h: Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
    opts: &ExecOpts,
) -> Result<Matrix> {
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let row_lo = plan.node_range(p_idx).0;
    let (flo, fhi) = plan.feat_range(m_idx);
    let pool = weights.config.aggregator == Aggregator::Pool;
    let storage_scope = StorageScope::open();
    let mut h = h;
    ctx.mem.alloc(h.nbytes()); // register the input tile
    let n_layers = weights.config.layers;
    assert_eq!(parts.len(), n_layers);
    for (l, part) in parts.iter().enumerate() {
        let phase = opts.phase + (l as u32) * 0x10;
        // Per-layer autotune override (DESIGN.md §Autotuning): schedule
        // only — every variant is bit-identical.
        let choice = crate::runtime::autotune::layer_choice(l);
        let _chunk_guard = choice.map(|c| crate::cluster::net::ChunkRowsGuard::pin(c.chunk_rows));
        let (mode, group_cols) =
            choice.map_or((opts.mode, opts.group_cols), |c| (c.mode, c.group_cols));
        let act = if l + 1 == n_layers { Act::None } else { Act::Relu };
        let bias = &weights.layer_b(l)[flo..fhi];
        // Self projection H W_self — kept resident, the epilogue reads it.
        let hs = deal_gemm(ctx, plan, &h, weights.layer_w(l), backend, phase)?;
        // Neighbor aggregate + self row + bias + act; both storage arms
        // and both aggregators share it, keeping them bit-identical.
        let epilogue = |r: usize, srow: &[f32], row: &mut [f32]| {
            for j in 0..row.len() {
                let v = row[j] + srow[j] + bias[j];
                row[j] = match act {
                    Act::None => v,
                    Act::Relu => v.max(0.0),
                };
            }
        };
        let mut agg;
        if !pool {
            // -- mean aggregator ------------------------------------------
            let hn = deal_gemm(ctx, plan, &h, weights.layer_w_neigh(l), backend, phase + 1)?;
            ctx.mem.free(h.nbytes());
            drop(h);
            // Per-edge mean weights `1/deg` (zero-degree rows have no
            // edges: their neighbor term stays zero).
            let neigh_w = ctx.compute(|| {
                let mut w = vec![0.0f32; part.csr.n_edges()];
                for r in 0..part.csr.n_rows {
                    let (lo, hi) = (part.csr.indptr[r] as usize, part.csr.indptr[r + 1] as usize);
                    if hi > lo {
                        let inv = 1.0 / (hi - lo) as f32;
                        for e in lo..hi {
                            w[e] = inv;
                        }
                    }
                }
                w
            });
            match &storage_scope {
                None => {
                    let input = SpmmInput {
                        plan,
                        g: &part.csr,
                        vals: EdgeValues::Scalar(&neigh_w),
                        h: &hn,
                    };
                    agg = deal_spmm(ctx, &input, backend, mode, group_cols, phase + 2);
                    ctx.mem.free(hn.nbytes());
                }
                Some(scope) => {
                    // Out-of-core: the neighbor tile moves to the paged
                    // tier and its RAM copy is dropped before the SPMM.
                    let pm = scope.spill(ctx, &format!("sage-hn-r{}-l{}", ctx.rank, l), &hn)?;
                    ctx.mem.free(hn.nbytes());
                    drop(hn);
                    let input = PagedSpmmInput {
                        plan,
                        g: &part.csr,
                        vals: EdgeValues::Scalar(&neigh_w),
                        h: &pm,
                        cache: &scope.cache,
                    };
                    agg = deal_spmm_paged(ctx, &input, backend, mode, group_cols, phase + 2)?;
                    scope.release(ctx, &pm);
                }
            }
        } else {
            // -- pool aggregator ------------------------------------------
            let mut hp = deal_gemm(ctx, plan, &h, weights.layer_w_pool(l), backend, phase + 1)?;
            ctx.mem.free(h.nbytes());
            drop(h);
            let bp = &weights.layer_b_pool(l)[flo..fhi];
            ctx.compute(|| {
                for r in 0..hp.rows {
                    let row = hp.row_mut(r);
                    for j in 0..row.len() {
                        row[j] = (row[j] + bp[j]).max(0.0);
                    }
                }
            });
            // Pooled rows for remote sources over GAT's v-exchange.
            let hp_remote = fetch_v(ctx, plan, part, &hp, phase + 2);
            let mx = ctx.compute(|| {
                let n_local = hp.rows;
                let hp_of = |s: usize| -> &[f32] {
                    if s >= row_lo && s < row_lo + n_local {
                        hp.row(s - row_lo)
                    } else {
                        let i = hp_remote
                            .0
                            .binary_search(&(s as u32))
                            .expect("pooled row not fetched");
                        hp_remote.1.row(i)
                    }
                };
                let mut mx = Matrix::zeros(part.csr.n_rows, fhi - flo);
                for r in 0..part.csr.n_rows {
                    let nbrs = part.csr.row(r);
                    if nbrs.is_empty() {
                        continue; // stays zero, matching the dense oracle
                    }
                    let mrow = mx.row_mut(r);
                    mrow.fill(f32::NEG_INFINITY);
                    for &s in nbrs {
                        for (m, &x) in mrow.iter_mut().zip(hp_of(s as usize)) {
                            *m = m.max(x);
                        }
                    }
                }
                mx
            });
            ctx.mem.alloc(mx.nbytes());
            ctx.mem.free(hp.nbytes() + hp_remote.1.nbytes());
            drop(hp);
            drop(hp_remote);
            agg = deal_gemm(ctx, plan, &mx, weights.layer_w_neigh(l), backend, phase + 3)?;
            ctx.mem.free(mx.nbytes());
        }
        ctx.compute(|| {
            for r in 0..agg.rows {
                epilogue(r, hs.row(r), agg.row_mut(r));
            }
        });
        ctx.mem.free(hs.nbytes());
        h = agg;
    }
    if let Some(scope) = &storage_scope {
        scope.finish(ctx);
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::model::reference::sage_reference;
    use crate::model::ModelConfig;
    use crate::primitives::{gather_tiles, scatter, ExecMode};
    use crate::sampling::sample_all_layers;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn run_distributed(
        g: &Csr,
        layers: &crate::sampling::LayerGraphs,
        h0: &Matrix,
        weights: &Arc<ModelWeights>,
        p: usize,
        m: usize,
    ) -> Matrix {
        let d = weights.config.dim;
        let plan = crate::partition::PartitionPlan::new(g.n_rows, d, p, m);
        let tiles = Arc::new(scatter(&plan, h0));
        let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::new();
        for pi in 0..plan.p {
            let (lo, hi) = plan.node_range(pi);
            parts_by_p
                .push(layers.layers.iter().map(|lg| LayerPart::new(lg.slice_rows(lo, hi))).collect());
        }
        let parts_by_p = Arc::new(parts_by_p);
        let plan2 = plan.clone();
        let weights2 = Arc::clone(weights);
        let cluster = Cluster::new(plan.world(), NetConfig::default());
        let (outs, _) = cluster
            .run(move |ctx| {
                let (pi, _) = plan2.coords_of(ctx.rank);
                let opts = ExecOpts { mode: ExecMode::Pipelined, group_cols: 16, phase: 0x40 };
                sage_forward(
                    ctx,
                    &plan2,
                    &parts_by_p[pi],
                    tiles[ctx.rank].clone(),
                    &weights2,
                    &crate::runtime::Native,
                    &opts,
                )
                .unwrap()
            })
            .unwrap();
        gather_tiles(&plan, d, &outs)
    }

    #[test]
    fn distributed_sage_matches_dense_reference_both_aggregators() {
        let el = rmat(7, 900, RmatParams::paper(), 31);
        let g = Csr::from(&el);
        let d = 12;
        let mut rng = Rng::new(9);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 77);
        for aggregator in [Aggregator::Mean, Aggregator::Pool] {
            let cfg = ModelConfig::sage(2, d, aggregator);
            let weights = Arc::new(ModelWeights::random(&cfg, 3));
            let expect = sage_reference(&layers, &h0, &weights);
            for (p, m) in [(2usize, 2usize), (4, 1), (1, 2), (2, 3)] {
                let got = run_distributed(&g, &layers, &h0, &weights, p, m);
                assert_close(&got.data, &expect.data, 2e-3, 2e-3).unwrap_or_else(|e| {
                    panic!("{:?} plan ({},{}): {}", aggregator, p, m, e)
                });
            }
        }
    }

    #[test]
    fn paged_sage_bit_identical_to_ram() {
        let el = rmat(7, 900, RmatParams::paper(), 31);
        let g = Csr::from(&el);
        let d = 12;
        let mut rng = Rng::new(9);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 77);
        for aggregator in [Aggregator::Mean, Aggregator::Pool] {
            let cfg = ModelConfig::sage(2, d, aggregator);
            let weights = Arc::new(ModelWeights::random(&cfg, 3));
            for (p, m) in [(2usize, 2usize), (1, 2)] {
                let ram = crate::storage::with_mem_budget(0, || {
                    run_distributed(&g, &layers, &h0, &weights, p, m)
                });
                for (budget, page_rows) in [(4096u64, 16usize), (1024, 1)] {
                    let paged = crate::storage::with_mem_budget(budget, || {
                        crate::storage::with_page_rows(page_rows, || {
                            run_distributed(&g, &layers, &h0, &weights, p, m)
                        })
                    });
                    assert_eq!(
                        paged, ram,
                        "{:?} plan ({},{}) budget {} page_rows {}",
                        aggregator, p, m, budget, page_rows
                    );
                }
            }
        }
    }
}
