//! GNN models assembled from the distributed primitives: GCN (mean
//! aggregation with self-loops) and GAT (4-head additive attention), the
//! two models the paper evaluates (§4.1).
//!
//! Both are expressed as *per-machine* forward functions over the
//! collaborative partition; single-machine dense references live in
//! [`reference`] and anchor the correctness tests (distributed output must
//! equal the dense oracle on the same sampled layer graphs).

pub mod gat;
pub mod gcn;
pub mod reference;

use crate::graph::Csr;
use crate::primitives::ExecMode;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Which model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gcn,
    Gat,
}

impl ModelKind {
    pub fn parse(s: &str) -> crate::Result<ModelKind> {
        match s {
            "gcn" => Ok(ModelKind::Gcn),
            "gat" => Ok(ModelKind::Gat),
            other => anyhow::bail!("unknown model '{}' (gcn|gat)", other),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Gat => "gat",
        }
    }
}

/// Model hyper-parameters. The paper sets hidden = input feature dim,
/// 3 layers, 4 GAT heads, fanout 50.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub kind: ModelKind,
    pub layers: usize,
    /// Input = hidden = output dimension.
    pub dim: usize,
    /// GAT heads (must divide `dim`; ignored for GCN).
    pub heads: usize,
}

impl ModelConfig {
    pub fn gcn(layers: usize, dim: usize) -> Self {
        ModelConfig { kind: ModelKind::Gcn, layers, dim, heads: 1 }
    }

    pub fn gat(layers: usize, dim: usize, heads: usize) -> Self {
        assert!(dim % heads == 0, "dim {} must be divisible by heads {}", dim, heads);
        ModelConfig { kind: ModelKind::Gat, layers, dim, heads }
    }

    /// Tensors per layer in the weights file.
    pub fn tensors_per_layer(&self) -> usize {
        match self.kind {
            ModelKind::Gcn => 2,              // W, b
            ModelKind::Gat => 4,              // W, b, a_src, a_dst
        }
    }
}

/// Model weights, replicated on every machine (they are small relative to
/// features — the paper's GEMM design relies on this).
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub config: ModelConfig,
    /// Flat list in layer order (see `runtime::weights`).
    pub tensors: Vec<Matrix>,
}

impl ModelWeights {
    /// Deterministic random initialization (Glorot-ish scale).
    pub fn random(config: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let d = config.dim;
        let scale = (1.0 / d as f32).sqrt();
        let mut tensors = Vec::new();
        for _ in 0..config.layers {
            tensors.push(Matrix::random(d, d, scale, &mut rng)); // W
            tensors.push(Matrix::zeros(1, d)); // b
            if config.kind == ModelKind::Gat {
                tensors.push(Matrix::random(d, config.heads, scale, &mut rng)); // a_src
                tensors.push(Matrix::random(d, config.heads, scale, &mut rng)); // a_dst
            }
        }
        ModelWeights { config: config.clone(), tensors }
    }

    /// Load from the python-trained interchange file.
    pub fn load(config: &ModelConfig, path: &std::path::Path) -> crate::Result<Self> {
        let tensors = crate::runtime::load_weights(path)?;
        let expect = config.layers * config.tensors_per_layer();
        anyhow::ensure!(
            tensors.len() == expect,
            "{} tensors in {}, expected {} for {:?}",
            tensors.len(),
            path.display(),
            expect,
            config.kind
        );
        Ok(ModelWeights { config: config.clone(), tensors })
    }

    pub fn layer_w(&self, l: usize) -> &Matrix {
        &self.tensors[l * self.config.tensors_per_layer()]
    }
    pub fn layer_b(&self, l: usize) -> &[f32] {
        &self.tensors[l * self.config.tensors_per_layer() + 1].data
    }
    pub fn layer_a_src(&self, l: usize) -> &Matrix {
        assert_eq!(self.config.kind, ModelKind::Gat);
        &self.tensors[l * 4 + 2]
    }
    pub fn layer_a_dst(&self, l: usize) -> &Matrix {
        assert_eq!(self.config.kind, ModelKind::Gat);
        &self.tensors[l * 4 + 3]
    }
}

/// Execution options threaded through the distributed forward passes.
#[derive(Clone, Copy, Debug)]
pub struct ExecOpts {
    pub mode: ExecMode,
    /// Max distinct columns per communication group (§3.5), 0 = unsplit.
    pub group_cols: usize,
    /// Base phase for message tags (layers offset from it).
    pub phase: u32,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts { mode: ExecMode::Pipelined, group_cols: 4096, phase: 0x100 }
    }
}

/// One machine's slice of the sampled layer graphs: for each GNN layer,
/// the partition's rows of `G_l` plus the GCN mean weights (1/(deg+1),
/// self-loop included as the `+1`).
#[derive(Clone, Debug)]
pub struct LayerPart {
    pub csr: Csr,
    /// Mean weights per edge: `1 / (deg(d) + 1)`.
    pub mean_w: Vec<f32>,
    /// Per local row self weight: `1 / (deg(d) + 1)`.
    pub self_w: Vec<f32>,
}

impl LayerPart {
    /// Build from a partition slice of a sampled layer graph.
    pub fn new(csr: Csr) -> Self {
        let mut mean_w = vec![0.0f32; csr.n_edges()];
        let mut self_w = vec![0.0f32; csr.n_rows];
        for d in 0..csr.n_rows {
            let (lo, hi) = (csr.indptr[d] as usize, csr.indptr[d + 1] as usize);
            let w = 1.0 / ((hi - lo) as f32 + 1.0);
            self_w[d] = w;
            for e in lo..hi {
                mean_w[e] = w;
            }
        }
        LayerPart { csr, mean_w, self_w }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_layout() {
        let cfg = ModelConfig::gat(2, 8, 4);
        let w = ModelWeights::random(&cfg, 1);
        assert_eq!(w.tensors.len(), 8);
        assert_eq!(w.layer_w(1).rows, 8);
        assert_eq!(w.layer_a_src(1).cols, 4);
        assert_eq!(w.layer_b(0).len(), 8);
    }

    #[test]
    fn layer_part_weights() {
        let csr = Csr::from_edges_rect(2, 4, &[(0, 0), (3, 0), (2, 1)]);
        let lp = LayerPart::new(csr);
        assert!((lp.mean_w[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((lp.self_w[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((lp.self_w[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("gcn").unwrap(), ModelKind::Gcn);
        assert_eq!(ModelKind::parse("gat").unwrap(), ModelKind::Gat);
        assert!(ModelKind::parse("mlp").is_err());
    }
}
