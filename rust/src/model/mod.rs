//! The model zoo assembled from the distributed primitives: GCN (mean
//! aggregation with self-loops), GAT (4-head additive attention) — the
//! two models the paper evaluates (§4.1) — and GraphSAGE (mean / max-pool
//! neighbor aggregation, the model every related system benchmarks).
//!
//! Every model implements [`GnnModel`]: a *per-machine* distributed
//! forward over the collaborative partition ([`GnnModel::forward`]), a
//! single-machine dense layer oracle ([`GnnModel::layer`], backing the
//! correctness tests and the delta engine's cached activations), and a
//! frontier-restricted per-row recompute ([`GnnModel::layer_rows`]) whose
//! output rows are bit-identical to the dense layer's — the property the
//! delta and temporal engines' exactness contracts stand on. The
//! coordinator, the delta path, and the paged path all dispatch through
//! [`ModelKind::model`] instead of hand-wiring per-model layer loops.

pub mod gat;
pub mod gcn;
pub mod reference;
pub mod sage;

use crate::cluster::Ctx;
use crate::graph::{Csr, NodeId};
use crate::partition::PartitionPlan;
use crate::primitives::ExecMode;
use crate::runtime::Backend;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::Result;

/// Which model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gcn,
    Gat,
    Sage,
}

impl ModelKind {
    /// Every model in the zoo, in registry order — the end-to-end parity
    /// matrix sweeps this list, and a trait-coverage guard asserts no
    /// kind is silently skipped.
    pub const ALL: [ModelKind; 3] = [ModelKind::Gcn, ModelKind::Gat, ModelKind::Sage];

    pub fn parse(s: &str) -> crate::Result<ModelKind> {
        match s {
            "gcn" => Ok(ModelKind::Gcn),
            "gat" => Ok(ModelKind::Gat),
            "sage" => Ok(ModelKind::Sage),
            other => anyhow::bail!(
                "unknown model '{}' (valid kinds: gcn, gat, sage)",
                other
            ),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Gat => "gat",
            ModelKind::Sage => "sage",
        }
    }

    /// The zoo entry for this kind — every dispatch site (coordinator,
    /// delta, baselines-adjacent tests) goes through this registry.
    pub fn model(&self) -> &'static dyn GnnModel {
        match self {
            ModelKind::Gcn => &gcn::GcnModel,
            ModelKind::Gat => &gat::GatModel,
            ModelKind::Sage => &sage::SageModel,
        }
    }
}

/// GraphSAGE neighbor aggregator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregator {
    /// Mean of neighbor projections (plus a separate self projection).
    Mean,
    /// Element-wise max over per-neighbor pooling MLP outputs.
    Pool,
}

impl Aggregator {
    pub fn parse(s: &str) -> crate::Result<Aggregator> {
        match s {
            "mean" => Ok(Aggregator::Mean),
            "pool" => Ok(Aggregator::Pool),
            other => anyhow::bail!("unknown aggregator '{}' (valid: mean, pool)", other),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Aggregator::Mean => "mean",
            Aggregator::Pool => "pool",
        }
    }
}

/// One GNN model's three faces (see the module docs). Implementations are
/// stateless unit structs; all model state lives in [`ModelWeights`].
///
/// Contract: for any partition slice `g` of a sampled layer graph whose
/// local row `i` is global row `row_base + i`, [`GnnModel::layer_rows`]
/// output row `j` must be **bit-identical** to row `rows[j]` of
/// [`GnnModel::layer`] over the stitched global graph — restriction may
/// never change arithmetic. The distributed [`GnnModel::forward`] matches
/// the dense layer loop within the float-accumulation-order tolerance and
/// is bit-identical across thread counts, chunk sizes, exec modes, and
/// memory budgets (the repo-wide determinism contract).
pub trait GnnModel: Sync {
    fn kind(&self) -> ModelKind;

    /// One dense layer over sampled graph `g` (global rows == `h.rows`).
    fn layer(&self, g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix;

    /// Frontier-restricted recompute of destination rows `rows` (sorted
    /// global ids, all within `[row_base, row_base + g.n_rows)`) against
    /// partition-local CSR `g` (local rows, global columns). Output row
    /// `j` corresponds to global row `rows[j]`.
    #[allow(clippy::too_many_arguments)]
    fn layer_rows(
        &self,
        g: &Csr,
        row_base: usize,
        h: &Matrix,
        weights: &ModelWeights,
        l: usize,
        relu: bool,
        rows: &[NodeId],
    ) -> Matrix;

    /// One machine's full distributed forward over the collaborative
    /// partition (same contract as the historical `gcn_forward`).
    fn forward(
        &self,
        ctx: &mut Ctx,
        plan: &PartitionPlan,
        parts: &[LayerPart],
        h: Matrix,
        weights: &ModelWeights,
        backend: &dyn Backend,
        opts: &ExecOpts,
    ) -> Result<Matrix>;
}

/// Model hyper-parameters. The paper sets hidden = input feature dim,
/// 3 layers, 4 GAT heads, fanout 50.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub kind: ModelKind,
    pub layers: usize,
    /// Input = hidden = output dimension.
    pub dim: usize,
    /// GAT heads (must divide `dim`; ignored for GCN and SAGE).
    pub heads: usize,
    /// GraphSAGE aggregator (ignored for GCN and GAT, which always use
    /// `Mean` — GCN's fixed mean is baked into `LayerPart`).
    pub aggregator: Aggregator,
}

impl ModelConfig {
    pub fn gcn(layers: usize, dim: usize) -> Self {
        ModelConfig { kind: ModelKind::Gcn, layers, dim, heads: 1, aggregator: Aggregator::Mean }
    }

    pub fn gat(layers: usize, dim: usize, heads: usize) -> Self {
        assert!(dim % heads == 0, "dim {} must be divisible by heads {}", dim, heads);
        ModelConfig { kind: ModelKind::Gat, layers, dim, heads, aggregator: Aggregator::Mean }
    }

    pub fn sage(layers: usize, dim: usize, aggregator: Aggregator) -> Self {
        ModelConfig { kind: ModelKind::Sage, layers, dim, heads: 1, aggregator }
    }

    /// Tensors per layer in the weights file.
    pub fn tensors_per_layer(&self) -> usize {
        match self.kind {
            ModelKind::Gcn => 2, // W, b
            ModelKind::Gat => 4, // W, b, a_src, a_dst
            ModelKind::Sage => match self.aggregator {
                Aggregator::Mean => 3, // W_self, b, W_neigh
                Aggregator::Pool => 5, // W_self, b, W_neigh, W_pool, b_pool
            },
        }
    }
}

/// Model weights, replicated on every machine (they are small relative to
/// features — the paper's GEMM design relies on this).
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub config: ModelConfig,
    /// Flat list in layer order (see `runtime::weights`).
    pub tensors: Vec<Matrix>,
}

impl ModelWeights {
    /// Deterministic random initialization (Glorot-ish scale).
    pub fn random(config: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let d = config.dim;
        let scale = (1.0 / d as f32).sqrt();
        let mut tensors = Vec::new();
        for _ in 0..config.layers {
            tensors.push(Matrix::random(d, d, scale, &mut rng)); // W (self for SAGE)
            tensors.push(Matrix::zeros(1, d)); // b
            match config.kind {
                ModelKind::Gcn => {}
                ModelKind::Gat => {
                    tensors.push(Matrix::random(d, config.heads, scale, &mut rng)); // a_src
                    tensors.push(Matrix::random(d, config.heads, scale, &mut rng)); // a_dst
                }
                ModelKind::Sage => {
                    tensors.push(Matrix::random(d, d, scale, &mut rng)); // W_neigh
                    if config.aggregator == Aggregator::Pool {
                        tensors.push(Matrix::random(d, d, scale, &mut rng)); // W_pool
                        tensors.push(Matrix::zeros(1, d)); // b_pool
                    }
                }
            }
        }
        ModelWeights { config: config.clone(), tensors }
    }

    /// Load from the python-trained interchange file.
    pub fn load(config: &ModelConfig, path: &std::path::Path) -> crate::Result<Self> {
        let tensors = crate::runtime::load_weights(path)?;
        let expect = config.layers * config.tensors_per_layer();
        anyhow::ensure!(
            tensors.len() == expect,
            "{} tensors in {}, expected {} for {:?}",
            tensors.len(),
            path.display(),
            expect,
            config.kind
        );
        Ok(ModelWeights { config: config.clone(), tensors })
    }

    pub fn layer_w(&self, l: usize) -> &Matrix {
        &self.tensors[l * self.config.tensors_per_layer()]
    }
    pub fn layer_b(&self, l: usize) -> &[f32] {
        &self.tensors[l * self.config.tensors_per_layer() + 1].data
    }
    pub fn layer_a_src(&self, l: usize) -> &Matrix {
        assert_eq!(self.config.kind, ModelKind::Gat);
        &self.tensors[l * 4 + 2]
    }
    pub fn layer_a_dst(&self, l: usize) -> &Matrix {
        assert_eq!(self.config.kind, ModelKind::Gat);
        &self.tensors[l * 4 + 3]
    }
    /// SAGE neighbor projection (`layer_w` is the self projection).
    pub fn layer_w_neigh(&self, l: usize) -> &Matrix {
        assert_eq!(self.config.kind, ModelKind::Sage);
        &self.tensors[l * self.config.tensors_per_layer() + 2]
    }
    /// SAGE pooling MLP weight (pool aggregator only).
    pub fn layer_w_pool(&self, l: usize) -> &Matrix {
        assert_eq!(self.config.aggregator, Aggregator::Pool);
        &self.tensors[l * self.config.tensors_per_layer() + 3]
    }
    /// SAGE pooling MLP bias (pool aggregator only).
    pub fn layer_b_pool(&self, l: usize) -> &[f32] {
        assert_eq!(self.config.aggregator, Aggregator::Pool);
        &self.tensors[l * self.config.tensors_per_layer() + 4].data
    }
}

/// Execution options threaded through the distributed forward passes.
#[derive(Clone, Copy, Debug)]
pub struct ExecOpts {
    pub mode: ExecMode,
    /// Max distinct columns per communication group (§3.5), 0 = unsplit.
    pub group_cols: usize,
    /// Base phase for message tags (layers offset from it).
    pub phase: u32,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts { mode: ExecMode::Pipelined, group_cols: 4096, phase: 0x100 }
    }
}

/// One machine's slice of the sampled layer graphs: for each GNN layer,
/// the partition's rows of `G_l` plus the GCN mean weights (1/(deg+1),
/// self-loop included as the `+1`).
#[derive(Clone, Debug)]
pub struct LayerPart {
    pub csr: Csr,
    /// Mean weights per edge: `1 / (deg(d) + 1)`.
    pub mean_w: Vec<f32>,
    /// Per local row self weight: `1 / (deg(d) + 1)`.
    pub self_w: Vec<f32>,
}

impl LayerPart {
    /// Build from a partition slice of a sampled layer graph.
    pub fn new(csr: Csr) -> Self {
        let mut mean_w = vec![0.0f32; csr.n_edges()];
        let mut self_w = vec![0.0f32; csr.n_rows];
        for d in 0..csr.n_rows {
            let (lo, hi) = (csr.indptr[d] as usize, csr.indptr[d + 1] as usize);
            let w = 1.0 / ((hi - lo) as f32 + 1.0);
            self_w[d] = w;
            for e in lo..hi {
                mean_w[e] = w;
            }
        }
        LayerPart { csr, mean_w, self_w }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_layout() {
        let cfg = ModelConfig::gat(2, 8, 4);
        let w = ModelWeights::random(&cfg, 1);
        assert_eq!(w.tensors.len(), 8);
        assert_eq!(w.layer_w(1).rows, 8);
        assert_eq!(w.layer_a_src(1).cols, 4);
        assert_eq!(w.layer_b(0).len(), 8);
    }

    #[test]
    fn layer_part_weights() {
        let csr = Csr::from_edges_rect(2, 4, &[(0, 0), (3, 0), (2, 1)]);
        let lp = LayerPart::new(csr);
        assert!((lp.mean_w[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((lp.self_w[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((lp.self_w[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("gcn").unwrap(), ModelKind::Gcn);
        assert_eq!(ModelKind::parse("gat").unwrap(), ModelKind::Gat);
        assert_eq!(ModelKind::parse("sage").unwrap(), ModelKind::Sage);
        let err = ModelKind::parse("mlp").unwrap_err().to_string();
        assert!(err.contains("gcn") && err.contains("gat") && err.contains("sage"), "{}", err);
        let err = Aggregator::parse("median").unwrap_err().to_string();
        assert!(err.contains("mean") && err.contains("pool"), "{}", err);
    }

    #[test]
    fn sage_weights_layout() {
        let mean = ModelWeights::random(&ModelConfig::sage(2, 8, Aggregator::Mean), 1);
        assert_eq!(mean.tensors.len(), 6);
        assert_eq!(mean.layer_w_neigh(1).rows, 8);
        let pool = ModelWeights::random(&ModelConfig::sage(2, 8, Aggregator::Pool), 1);
        assert_eq!(pool.tensors.len(), 10);
        assert_eq!(pool.layer_w_pool(1).cols, 8);
        assert_eq!(pool.layer_b_pool(0).len(), 8);
    }

    #[test]
    fn registry_covers_all_kinds() {
        for kind in ModelKind::ALL {
            assert_eq!(kind.model().kind(), kind);
            assert_eq!(ModelKind::parse(kind.name()).unwrap(), kind);
        }
    }
}
