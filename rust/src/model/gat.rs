//! Distributed GAT forward pass.
//!
//! GAT's additive attention is *separable*: the raw score of edge `(s, d)`
//! for head `h` is `LeakyReLU(u[d,h] + v[s,h])` with `u = Z·a_dst`,
//! `v = Z·a_src` — so attention never needs the full SDDMM dot product,
//! only an exchange of the per-node `v` scalars (heads-wide), after which
//! the softmax is entirely local (the 1-D partition keeps every
//! destination's full edge list on its machines). The aggregation is the
//! paper's *three-tensor SPMM* (`E[i][] ⊙ H'[][i]`): per-edge per-head α
//! weights multiplying the feature columns of their head
//! (`EdgeValues::PerHead`).
//!
//! Layout requirement: `dim % M == 0` and `heads % M == 0` so feature-part
//! boundaries align with head boundaries (checked at entry). The paper's
//! configuration (4 heads, M ∈ {1,2,4}) satisfies it.
//!
//! (The full SDDMM primitive is still exercised — Fig. 18's bench and
//! models with non-separable attention use `primitives::sddmm`.)

use crate::cluster::{Ctx, Payload, Tag};
use crate::graph::{Csr, NodeId};
use crate::partition::PartitionPlan;
use crate::primitives::gemm::deal_gemm;
use crate::primitives::groups::build_groups;
use crate::primitives::spmm::{
    deal_spmm, deal_spmm_paged, feature_server, EdgeValues, PagedSpmmInput, SpmmInput,
};
use crate::runtime::{par, Act, Backend};
use crate::tensor::{leaky_relu, Matrix};
use crate::util::even_ranges;
use crate::Result;

use super::gcn::StorageScope;
use super::{reference, ExecOpts, GnnModel, LayerPart, ModelKind, ModelWeights};

const COUNT_SEQ: u32 = u32::MAX;
const RESP_BIT: u32 = 0x8000_0000;

/// Model-zoo entry for GAT (see [`crate::model::GnnModel`]).
pub struct GatModel;

impl GnnModel for GatModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Gat
    }

    fn layer(&self, g: &Csr, h: &Matrix, weights: &ModelWeights, l: usize, relu: bool) -> Matrix {
        reference::gat_layer(g, h, weights, l, relu)
    }

    fn layer_rows(
        &self,
        g: &Csr,
        row_base: usize,
        h: &Matrix,
        weights: &ModelWeights,
        l: usize,
        relu: bool,
        rows: &[NodeId],
    ) -> Matrix {
        reference::gat_layer_rows(g, row_base, h, weights, l, relu, rows)
    }

    fn forward(
        &self,
        ctx: &mut Ctx,
        plan: &PartitionPlan,
        parts: &[LayerPart],
        h: Matrix,
        weights: &ModelWeights,
        backend: &dyn Backend,
        opts: &ExecOpts,
    ) -> Result<Matrix> {
        gat_forward(ctx, plan, parts, h, weights, backend, opts)
    }
}

/// One machine's full GAT forward. Same contract as `gcn_forward`.
pub fn gat_forward(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    parts: &[LayerPart],
    h: Matrix,
    weights: &ModelWeights,
    backend: &dyn Backend,
    opts: &ExecOpts,
) -> Result<Matrix> {
    let heads = weights.config.heads;
    let d = weights.config.dim;
    anyhow::ensure!(
        d % plan.m == 0 && heads % plan.m == 0,
        "GAT needs dim ({}) and heads ({}) divisible by feature parts ({})",
        d,
        heads,
        plan.m
    );
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let row_lo = plan.node_range(p_idx).0;
    let (flo, fhi) = plan.feat_range(m_idx);
    let head_dim = d / heads;
    // my heads and the local column→local-head map
    let head_bounds = even_ranges(heads, plan.m);
    let (hlo, hhi) = (head_bounds[m_idx], head_bounds[m_idx + 1]);
    let my_heads = hhi - hlo;
    let col_head: Vec<u8> = (flo..fhi).map(|c| (c / head_dim - hlo) as u8).collect();

    let storage_scope = StorageScope::open();
    let mut h = h;
    ctx.mem.alloc(h.nbytes()); // register the input tile
    let n_layers = weights.config.layers;
    for (l, part) in parts.iter().enumerate() {
        let phase = opts.phase + (l as u32) * 0x10;
        // Per-layer autotune override (DESIGN.md §Autotuning): an
        // installed plan's choice replaces the fixed `ExecOpts` mode/tile
        // and pins the layer's chunk granularity. Schedule-only — every
        // variant is bit-identical.
        let choice = crate::runtime::autotune::layer_choice(l);
        let _chunk_guard = choice.map(|c| crate::cluster::net::ChunkRowsGuard::pin(c.chunk_rows));
        let (mode, group_cols) =
            choice.map_or((opts.mode, opts.group_cols), |c| (c.mode, c.group_cols));
        // 1. Projection Z = H W.
        let z = deal_gemm(ctx, plan, &h, weights.layer_w(l), backend, phase)?;
        ctx.mem.free(h.nbytes());
        drop(h);
        // 2. Attention scalars u (dst role), v (src role) — tiles hold my
        //    heads' columns.
        let u = deal_gemm(ctx, plan, &z, weights.layer_a_dst(l), backend, phase + 1)?;
        let v = deal_gemm(ctx, plan, &z, weights.layer_a_src(l), backend, phase + 2)?;
        debug_assert_eq!(u.cols, my_heads);
        // 3. Fetch v rows for remote sources, then compute α locally.
        let v_remote = fetch_v(ctx, plan, part, &v, phase + 3);
        let alpha = ctx.compute(|| {
            compute_alpha(part, &u, &v, &v_remote, row_lo, my_heads)
        });
        ctx.mem.alloc((alpha.0.len() * 4) as u64);
        ctx.mem.free(u.nbytes() + v.nbytes() + v_remote.1.nbytes());
        drop(u);
        drop(v);
        drop(v_remote);
        // 4. Three-tensor SPMM aggregation with α as edge features, then
        //    5. the self-edge term + bias + activation.
        let act = if l + 1 == n_layers { Act::None } else { Act::Relu };
        let bias = &weights.layer_b(l)[flo..fhi];
        // One definition of the self-edge + bias + act epilogue; the two
        // arms differ only in where `zrow` is read from (resident tile vs
        // faulted band) — the shared kernel keeps them bit-identical.
        let epilogue = |r: usize, zrow: &[f32], row: &mut [f32]| {
            let self_a = &alpha.1[r * my_heads..(r + 1) * my_heads];
            for j in 0..row.len() {
                let val = row[j] + self_a[col_head[j] as usize] * zrow[j] + bias[j];
                row[j] = match act {
                    Act::None => val,
                    Act::Relu => val.max(0.0),
                };
            }
        };
        let mut agg;
        match &storage_scope {
            None => {
                let input = SpmmInput {
                    plan,
                    g: &part.csr,
                    vals: EdgeValues::PerHead {
                        vals: &alpha.0,
                        heads: my_heads,
                        col_head: &col_head,
                    },
                    h: &z,
                };
                agg = deal_spmm(ctx, &input, backend, mode, group_cols, phase + 4);
                ctx.compute(|| {
                    for r in 0..agg.rows {
                        epilogue(r, z.row(r), agg.row_mut(r));
                    }
                });
                ctx.mem.free(z.nbytes());
            }
            Some(scope) => {
                // Out-of-core: `Z` (already consumed by the u/v GEMMs and
                // the attention pass) moves to the paged tier; the SPMM
                // and the self-edge pass fault rows back through the
                // budgeted cache. Same arithmetic order → bit-identical.
                let pz = scope.spill(ctx, &format!("gat-z-r{}-l{}", ctx.rank, l), &z)?;
                ctx.mem.free(z.nbytes());
                drop(z);
                let input = PagedSpmmInput {
                    plan,
                    g: &part.csr,
                    vals: EdgeValues::PerHead {
                        vals: &alpha.0,
                        heads: my_heads,
                        col_head: &col_head,
                    },
                    h: &pz,
                    cache: &scope.cache,
                };
                agg = deal_spmm_paged(ctx, &input, backend, mode, group_cols, phase + 4)?;
                let mut io_total = 0.0f64;
                let mut r0 = 0usize;
                while r0 < agg.rows {
                    let r1 = (r0 + scope.page_rows).min(agg.rows);
                    let (band, io) = pz.band_shared(&scope.cache, r0, r1)?;
                    io_total += io;
                    ctx.compute(|| {
                        for r in r0..r1 {
                            epilogue(r, band.row(r - r0), agg.row_mut(r));
                        }
                    });
                    r0 = r1;
                }
                ctx.advance(io_total);
                scope.release(ctx, &pz);
            }
        }
        ctx.mem.free((alpha.0.len() * 4) as u64);
        h = agg;
    }
    if let Some(scope) = &storage_scope {
        scope.finish(ctx);
    }
    Ok(h)
}

/// Fetch `v` rows (my heads) for every remote source referenced by the
/// partition: one monolithic exchange (v is `heads/M` floats per node, two
/// orders of magnitude lighter than the feature exchange). Returns
/// `(sorted remote ids, stacked rows)` per source partition flattened into
/// lookup vectors. Shape-agnostic over `v.cols` — SAGE's pool aggregator
/// reuses it to exchange pooled feature-window rows.
pub(crate) fn fetch_v(
    ctx: &mut Ctx,
    plan: &PartitionPlan,
    part: &LayerPart,
    v: &Matrix,
    phase: u32,
) -> (Vec<u32>, Matrix) {
    let (p_idx, m_idx) = plan.coords_of(ctx.rank);
    let row_lo = plan.node_range(p_idx).0;
    let ones = vec![1.0f32; part.csr.n_edges()];
    let groups = build_groups(&part.csr, &ones, plan, p_idx, 0);
    // counts to my column group peers
    let mut per_peer = vec![0u32; plan.p];
    for g in &groups {
        if !g.local {
            per_peer[g.src_part] += 1;
        }
    }
    for q in 0..plan.p {
        if q != p_idx {
            ctx.send_service(
                plan.rank_of(q, m_idx),
                Tag::of(phase, COUNT_SEQ),
                Payload::U32(vec![per_peer[q]]),
            );
        }
    }
    let expected_peers = plan.p - 1;
    ctx.with_server(
        |sctx| feature_server(sctx, v, row_lo, expected_peers, phase),
        |ctx| {
            let mut ids: Vec<u32> = Vec::new();
            let mut rows: Vec<Matrix> = Vec::new();
            for (seq, g) in groups.iter().enumerate() {
                if g.local {
                    continue;
                }
                let server = plan.rank_of(g.src_part, m_idx);
                ctx.send_service(server, Tag::of(phase, seq as u32), Payload::U32(g.cols.clone()));
            }
            for (seq, g) in groups.iter().enumerate() {
                if g.local {
                    continue;
                }
                let server = plan.rank_of(g.src_part, m_idx);
                let block = ctx.recv_matrix(server, Tag::of(phase, seq as u32 | RESP_BIT));
                ids.extend_from_slice(&g.cols);
                rows.push(block);
            }
            let stacked = if rows.is_empty() {
                Matrix::zeros(0, v.cols)
            } else {
                Matrix::vcat(&rows.iter().collect::<Vec<_>>())
            };
            ctx.mem.alloc(stacked.nbytes());
            // ids arrive sorted per group but groups may interleave ranges;
            // sort the combined index for binary-search lookup.
            let mut order: Vec<usize> = (0..ids.len()).collect();
            order.sort_by_key(|&i| ids[i]);
            let sorted_ids: Vec<u32> = order.iter().map(|&i| ids[i]).collect();
            let mut sorted_rows = Matrix::zeros(stacked.rows, stacked.cols);
            for (to, &from) in order.iter().enumerate() {
                sorted_rows.row_mut(to).copy_from_slice(stacked.row(from));
            }
            (sorted_ids, sorted_rows)
        },
    )
}

/// Work floor (edge × head ops) below which attention stays serial.
const MIN_ALPHA_WORK: u64 = 32 * 1024;

/// Compute per-edge per-head softmax weights and the self-edge weights.
/// Returns `(alpha_edges [n_edges × my_heads], alpha_self [rows × my_heads])`.
///
/// The softmax is per destination row, so rows split into degree-balanced
/// parallel bands: band `b` owns the contiguous `alpha` slice of its rows'
/// edges and its `alpha_self` rows, and every row's score/softmax sequence
/// is exactly the scalar one — bit-identical at any thread count.
fn compute_alpha(
    part: &LayerPart,
    u: &Matrix,
    v: &Matrix,
    v_remote: &(Vec<u32>, Matrix),
    row_lo: usize,
    my_heads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let csr = &part.csr;
    let mut alpha = vec![0.0f32; csr.n_edges() * my_heads];
    let mut alpha_self = vec![0.0f32; csr.n_rows * my_heads];
    let bounds = par::weighted_bands(
        csr.n_rows,
        |r| (csr.indptr[r + 1] - csr.indptr[r] + 1) * my_heads as u64,
        MIN_ALPHA_WORK,
    );
    let ecuts: Vec<usize> = bounds.iter().map(|&r| csr.indptr[r] as usize * my_heads).collect();
    let alpha_bands = par::split_at_cuts(&mut alpha, &ecuts);
    let self_bands = par::split_rows(&mut alpha_self, &bounds, my_heads);
    let parts: Vec<_> = self_bands.into_iter().zip(alpha_bands).collect();
    par::run_parts(parts, |_, ((rows, self_band), alpha_band)| {
        let n_local = v.rows;
        let v_of = |s: usize| -> &[f32] {
            if s >= row_lo && s < row_lo + n_local {
                v.row(s - row_lo)
            } else {
                let i = v_remote.0.binary_search(&(s as u32)).expect("v row not fetched");
                v_remote.1.row(i)
            }
        };
        let elo = csr.indptr[rows.start] as usize;
        for r in rows.clone() {
            let (lo, hi) = (csr.indptr[r] as usize, csr.indptr[r + 1] as usize);
            let urow = u.row(r);
            for h in 0..my_heads {
                // raw scores
                let self_score = leaky_relu(urow[h] + v.row(r)[h]);
                let mut mx = self_score;
                for e in lo..hi {
                    let s = csr.indices[e] as usize;
                    let sc = leaky_relu(urow[h] + v_of(s)[h]);
                    alpha_band[(e - elo) * my_heads + h] = sc;
                    if sc > mx {
                        mx = sc;
                    }
                }
                // softmax
                let mut sum = (self_score - mx).exp();
                let self_e = sum;
                for e in lo..hi {
                    let x = (alpha_band[(e - elo) * my_heads + h] - mx).exp();
                    alpha_band[(e - elo) * my_heads + h] = x;
                    sum += x;
                }
                for e in lo..hi {
                    alpha_band[(e - elo) * my_heads + h] /= sum;
                }
                self_band[(r - rows.start) * my_heads + h] = self_e / sum;
            }
        }
    });
    (alpha, alpha_self)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};
    use crate::graph::rmat::{rmat, RmatParams};
    use crate::graph::Csr;
    use crate::model::reference::gat_reference;
    use crate::model::ModelConfig;
    use crate::primitives::{gather_tiles, scatter, ExecMode};
    use crate::sampling::sample_all_layers;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn distributed_gat_matches_dense_reference() {
        let el = rmat(7, 700, RmatParams::paper(), 41);
        let g = Csr::from(&el);
        let d = 16;
        let heads = 4;
        let mut rng = Rng::new(19);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 78);
        let cfg = ModelConfig::gat(2, d, heads);
        let weights = ModelWeights::random(&cfg, 13);
        let expect = gat_reference(&layers, &h0, &weights);

        for (p, m) in [(2usize, 2usize), (2, 1), (1, 4), (4, 2)] {
            let plan = crate::partition::PartitionPlan::new(g.n_rows, d, p, m);
            let tiles = Arc::new(scatter(&plan, &h0));
            let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::new();
            for pi in 0..plan.p {
                let (lo, hi) = plan.node_range(pi);
                parts_by_p.push(
                    layers
                        .layers
                        .iter()
                        .map(|lg| LayerPart::new(lg.slice_rows(lo, hi)))
                        .collect(),
                );
            }
            let parts_by_p = Arc::new(parts_by_p);
            let plan2 = plan.clone();
            let weights2 = Arc::new(weights.clone());
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (outs, _) = cluster
                .run(move |ctx| {
                    let (pi, _) = plan2.coords_of(ctx.rank);
                    let opts = ExecOpts { mode: ExecMode::Pipelined, group_cols: 8, phase: 0x40 };
                    gat_forward(
                        ctx,
                        &plan2,
                        &parts_by_p[pi],
                        tiles[ctx.rank].clone(),
                        &weights2,
                        &crate::runtime::Native,
                        &opts,
                    )
                    .unwrap()
                })
                .unwrap();
            let got = gather_tiles(&plan, d, &outs);
            assert_close(&got.data, &expect.data, 2e-3, 2e-3)
                .unwrap_or_else(|e| panic!("plan ({},{}): {}", p, m, e));
        }
    }

    #[test]
    fn paged_gat_bit_identical_to_ram() {
        let el = rmat(7, 700, RmatParams::paper(), 41);
        let g = Csr::from(&el);
        let d = 16;
        let heads = 4;
        let mut rng = Rng::new(19);
        let h0 = Matrix::random(g.n_rows, d, 1.0, &mut rng);
        let layers = sample_all_layers(&g, 2, 4, 78);
        let cfg = ModelConfig::gat(2, d, heads);
        let weights = Arc::new(ModelWeights::random(&cfg, 13));

        let run = |p: usize, m: usize| -> Matrix {
            let plan = crate::partition::PartitionPlan::new(g.n_rows, d, p, m);
            let tiles = Arc::new(scatter(&plan, &h0));
            let mut parts_by_p: Vec<Vec<LayerPart>> = Vec::new();
            for pi in 0..plan.p {
                let (lo, hi) = plan.node_range(pi);
                parts_by_p.push(
                    layers.layers.iter().map(|lg| LayerPart::new(lg.slice_rows(lo, hi))).collect(),
                );
            }
            let parts_by_p = Arc::new(parts_by_p);
            let plan2 = plan.clone();
            let weights2 = Arc::clone(&weights);
            let cluster = Cluster::new(plan.world(), NetConfig::default());
            let (outs, _) = cluster
                .run(move |ctx| {
                    let (pi, _) = plan2.coords_of(ctx.rank);
                    let opts = ExecOpts { mode: ExecMode::Pipelined, group_cols: 8, phase: 0x40 };
                    gat_forward(
                        ctx,
                        &plan2,
                        &parts_by_p[pi],
                        tiles[ctx.rank].clone(),
                        &weights2,
                        &crate::runtime::Native,
                        &opts,
                    )
                    .unwrap()
                })
                .unwrap();
            gather_tiles(&plan, d, &outs)
        };

        for (p, m) in [(2usize, 2usize), (1, 4)] {
            let ram = crate::storage::with_mem_budget(0, || run(p, m));
            for (budget, page_rows) in [(4096u64, 16usize), (2048, 1)] {
                let paged = crate::storage::with_mem_budget(budget, || {
                    crate::storage::with_page_rows(page_rows, || run(p, m))
                });
                assert_eq!(
                    paged, ram,
                    "plan ({},{}) budget {} page_rows {}",
                    p, m, budget, page_rows
                );
            }
        }
    }
}
