//! Command-line interface (hand-rolled: no `clap` offline).
//!
//! ```text
//! deal run        [--config FILE] [--set section.key=value ...]
//! deal gen-dataset --name NAME --scale S --out PATH
//! deal gen-labelled --nodes N --classes C --degree D --dim F --out DIR
//! deal datasets
//! deal help
//! ```

use std::path::PathBuf;

use crate::config::DealConfig;
use crate::coordinator::Pipeline;
use crate::graph::datasets;
use crate::util::{human_bytes, human_secs};
use crate::Result;

const USAGE: &str = "deal — Distributed End-to-End GNN Inference for All Nodes

USAGE:
  deal run [--config FILE] [--set section.key=value]...
           [--autotune]                                   run the pipeline
  deal serve [--config FILE] [--set section.key=value]...
             [--requests N] [--workers W] [--batch B] [--refresh R]
             [--storage-dir DIR] [--resume]
             [--membership-schedule S]                    refresh + serve the table
  deal stream [--config FILE] [--set section.key=value]...
              [--batches N] [--churn F] [--feat-churn F] [--verify]
                                                          replay streaming updates
  deal traffic [--config FILE] [--set section.key=value]...
               [--requests N] [--rate R] [--policy P] [--speed S]
               [--workers W] [--queue Q] [--sweep]
               [--trace-out PATH] [--trace-in PATH]       replay production traffic
  deal temporal [--config FILE] [--set section.key=value]...
                [--epochs N] [--snapshot-every T] [--retain R]
                [--churn F] [--feat-churn F] [--at E] [--probes Q]
                [--storage-dir DIR] [--resume] [--verify] replay a timestamped
                                                          edge stream into epoch
                                                          snapshots
  deal gen-dataset --name NAME [--scale S] --out PATH     write an edge file
  deal gen-labelled [--nodes N] [--classes C] [--degree D]
                    [--dim F] [--seed S] --out DIR        write the SBM study set
  deal datasets                                           list the registry
  deal help                                               this message

`serve` runs the inference pipeline once, shards the refreshed embedding
table with the inference layout, then drives a synthetic Embed/Similar
workload through both the sequential baseline and the batched sharded
worker pool (with R mid-load refresh swaps), reporting p50/p99/throughput.

`stream` opens the streaming-update loop: build the baseline state once,
then replay N synthetic update batches (each editing a `--churn` fraction
of the edges, half insertions half removals, plus a `--feat-churn`
fraction of feature rows), publishing a *delta epoch* per batch — only
affected rows are re-inferred and patched into the serving table.
`--verify` finishes with a from-scratch full recompute and asserts the
incremental state matches it.

With `--storage-dir DIR` (sugar for `--set storage.dir=DIR`; the
`DEAL_STORAGE_DIR` env works too) `serve` runs **durably**: the refreshed
table is checkpointed into DIR and every published epoch — full refreshes
and delta patches alike — is journaled to a checksummed write-ahead log
*before* it becomes visible, so no client-visible state can be lost to a
crash. `deal serve --resume` then skips the inference pipeline entirely:
it replays log-over-checkpoint from DIR and rebuilds the exact (bit-
identical) pre-crash serving table. The same directory also hosts the
out-of-core tier's spill pages.

`deal serve --membership-schedule \"leave:2,join:2,kill:1\"` finishes with
an elastic-membership phase: the refreshed table is re-hosted on a
simulated cluster whose world then shrinks, grows, and kills ranks per
the schedule. Each event bumps an epoch-fenced membership epoch,
migrates only the row bands changing owner (a killed rank's band is
rebuilt from its durable shard store when a storage directory is set),
and hands the reassembled table to the serving pool through the same
double-buffered epoch swap a refresh uses. The command re-serves a
pinned workload after every event and hard-fails unless responses stay
bit-identical across all membership epochs.

`temporal` drives the temporal embedding engine: build the baseline graph
as epoch 0, then replay N epoch windows of a deterministic timestamped
edge stream (each window churns a `--churn` fraction of the edges and a
`--feat-churn` fraction of the feature rows, tick-spread across
`--snapshot-every` ticks), sealing one **versioned epoch snapshot** per
window into a retention-bounded index (`--retain`, oldest evicted
first). `--at E` then answers a Zipf-skewed probe workload *as of* epoch
E through the serving pool — resident epochs serve directly; evicted
ones are reconstructed (digest-verified) from the durable journal when
`--storage-dir` is set. `--resume` rebuilds the whole epoch index from
that journal instead of starting over, and `--verify` finishes with a
cold full-graph recompute, asserting the latest snapshot is
**bit-identical** to it (the temporal contract; DESIGN.md §Temporal).

`traffic` generates (or loads, `--trace-in`) a deterministic production
trace — Zipfian key skew, diurnal + bursty Poisson arrivals, interleaved
churn batches — and replays it against the serving pool in **open-loop**
mode: requests are injected on the trace's schedule whether or not the
pool keeps up, so overload sheds load at admission instead of silently
slowing the generator. Reports per-class (embed/similar)
p50/p99/p999 latency, goodput, and admission rejects. `--policy` picks
the batch-formation policy (`depth`, `deadline[:US]`, `size[:IDS]`);
`--sweep` instead replays the trace in sequenced mode under every policy
and asserts bit-identical responses. `--trace-out` writes the versioned
trace artifact (byte-identical for the same seed + config).

Every computing command (run, serve, stream, gen-dataset, gen-labelled)
accepts `--threads N`: the intra-rank pool size for the parallel kernels
(for config-driven commands, equivalent to `--set exec.threads=N`; 0 or
unset = auto: the `DEAL_THREADS` env var, else all available cores).
Results are bit-identical at every thread count.

The config-driven commands (run, serve, stream) also accept
`--chunk-rows N` (sugar for `--set pipeline.chunk_rows=N`): the row-band
granularity of pipelined tensor transfers — receivers compute on early
bands while later bands are in flight. 0 = monolithic transfers; library
and test runs can use the `DEAL_CHUNK_ROWS` env instead. Results are
bit-identical at every chunk size.

They also accept `--mem-budget BYTES` (sugar for
`--set storage.budget_bytes=BYTES`; accepts k/m/g suffixes, e.g. `64m`):
the per-rank byte budget for the out-of-core paged storage tier. With a
budget set, projected feature/activation tables and layer-graph
adjacency spill to tempfile-backed pages behind a budgeted cache, and
`deal serve` stages refreshed serving epochs on disk instead of doubling
table RAM. 0 (the default) keeps everything resident. Library and test
runs can use the `DEAL_MEM_BUDGET` env instead; page granularity comes
from `storage.page_rows` / `DEAL_PAGE_ROWS`. Results are bit-identical
at every budget and page size — only page-fault counts and simulated
I/O time change.

`run` also accepts `--autotune` (sugar for `--set exec.autotune=1`): the
coordinator runs a short seeded micro-calibration pass (cached in a
versioned, checksummed sidecar — `DEAL_AUTOTUNE_CACHE`, default
`target/autotune/calibration.json` — so repeat runs skip it), then plans
exec mode, chunk granularity, ring direction, pool width, and page size
per layer from the measured constants and the run's cost model instead
of the fixed defaults. Library and test runs can use the `DEAL_AUTOTUNE`
env instead. Plans change simulated and wall time only — outputs stay
bit-identical to every fixed configuration.

Config keys (see rust/src/config.rs): dataset.name, dataset.scale,
cluster.machines, cluster.feature_parts, cluster.bandwidth_gbps,
cluster.latency_us, model.kind, model.layers, model.fanout, model.weights,
exec.mode, exec.group_cols, exec.backend, exec.feature_prep, exec.threads,
exec.autotune, exec.seed, pipeline.chunk_rows, storage.budget_bytes,
storage.page_rows, storage.dir, traffic.requests, traffic.rate,
traffic.zipf_s, traffic.diurnal, traffic.burst, traffic.similar_frac,
traffic.churn_batches, traffic.policy, traffic.speed
";

/// Entry point used by `main.rs`. Exits the process on error.
pub fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

/// Dispatch a command line (testable).
pub fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("stream") => cmd_stream(&args[1..]),
        Some("traffic") => cmd_traffic(&args[1..]),
        Some("temporal") => cmd_temporal(&args[1..]),
        Some("gen-dataset") => cmd_gen_dataset(&args[1..]),
        Some("gen-labelled") => cmd_gen_labelled(&args[1..]),
        Some("datasets") => cmd_datasets(),
        Some("help") | None => {
            println!("{}", USAGE);
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command '{}'\n{}", other, USAGE),
    }
}

/// Pull `--flag value` pairs out of an arg list.
pub fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Build a config from `--config FILE` plus `--set k=v` overrides and the
/// `--threads` shorthand (shared by `run`, `serve`, and `stream`). Pure
/// parsing — `apply_threads` commits the pool knob at execution time.
fn cfg_from_args(args: &[String]) -> Result<DealConfig> {
    let mut cfg = match flag_value(args, "--config") {
        Some(path) => DealConfig::from_file(std::path::Path::new(path))?,
        None => DealConfig::default(),
    };
    // apply every --set k=v in order
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--set" {
            let kv = args
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("--set needs key=value"))?;
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got '{}'", kv))?;
            cfg.set(k, v)?;
            i += 2;
        } else {
            i += 1;
        }
    }
    // `--threads N` is sugar for `--set exec.threads=N`.
    if let Some(t) = flag_value(args, "--threads") {
        cfg.exec.threads = t.parse()?;
    }
    // `--chunk-rows N` is sugar for `--set pipeline.chunk_rows=N`.
    if let Some(c) = flag_value(args, "--chunk-rows") {
        cfg.pipeline.chunk_rows = c.parse()?;
    }
    // `--mem-budget B` is sugar for `--set storage.budget_bytes=B`.
    if let Some(b) = flag_value(args, "--mem-budget") {
        cfg.storage.budget_bytes = crate::storage::parse_bytes(b)?;
    }
    // `--storage-dir D` is sugar for `--set storage.dir=D`.
    if let Some(d) = flag_value(args, "--storage-dir") {
        cfg.storage.dir = d.to_string();
    }
    // `--autotune` (boolean, no value) is sugar for `--set exec.autotune=1`.
    if args.iter().any(|a| a == "--autotune") {
        cfg.exec.autotune = true;
    }
    Ok(cfg)
}

/// Apply the process-wide runtime knobs (intra-rank pool size, pipelined
/// chunk granularity, storage budget/page size). Called by the command
/// entry points right before execution starts — parsing a config stays
/// side-effect free.
fn apply_threads(cfg: &DealConfig) {
    crate::runtime::par::set_threads(cfg.exec.threads);
    crate::cluster::net::set_chunk_rows(cfg.pipeline.chunk_rows);
    crate::storage::set_mem_budget(cfg.storage.budget_bytes);
    crate::storage::set_page_rows(cfg.storage.page_rows);
    crate::storage::set_storage_dir(&cfg.storage.dir);
    // Only an explicit opt-in overrides; leaving the knob untouched keeps
    // the DEAL_AUTOTUNE env fallback live (mirrors threads' 0 = auto).
    if cfg.exec.autotune {
        crate::runtime::autotune::set_autotune(true);
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    apply_threads(&cfg);
    println!(
        "deal run: dataset={} scale={} machines={} (P×M = {:?}) model={} fanout={} mode={} backend={} prep={}",
        cfg.dataset.name,
        cfg.dataset.scale,
        cfg.cluster.machines,
        cfg.parts()?,
        cfg.model.kind,
        cfg.model.fanout,
        cfg.exec.mode,
        cfg.exec.backend,
        cfg.exec.feature_prep,
    );
    let report = Pipeline::new(cfg).run()?;
    println!("\nstage breakdown (simulated cluster time):");
    for s in &report.stages.0 {
        println!(
            "  {:<12} {:>12}   (wall {:>12})",
            s.name,
            human_secs(s.sim_secs),
            human_secs(s.wall_secs)
        );
    }
    println!(
        "  {:<12} {:>12}   pre-processing fraction {:.1}%",
        "TOTAL",
        human_secs(report.stages.total()),
        report.stages.preprocessing_fraction() * 100.0
    );
    println!("  peak tracked memory (max machine): {}", human_bytes(report.max_peak_mem));
    let (faults, spill) = report
        .stages
        .0
        .iter()
        .filter_map(|s| s.cluster.as_ref())
        .fold((0u64, 0u64), |(f, b), c| (f + c.total_page_faults(), b + c.total_spill_bytes()));
    if faults > 0 || spill > 0 {
        println!(
            "  storage: {} page faults, {} spill traffic (budget {})",
            faults,
            human_bytes(spill),
            human_bytes(crate::storage::mem_budget()),
        );
    }
    if let Some(e) = &report.embeddings {
        println!("  embeddings: {} × {}", e.rows, e.cols);
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use crate::runtime::backend_from_config;
    use crate::serve::{
        response_digest, serve_workload, serve_workload_pooled, synthetic_workload,
        EmbeddingServer, PoolOpts, Refresher, ServePool, TableCell,
    };
    use crate::storage::{DurableOptions, DurableStore};
    use crate::util::rng::Rng;
    use std::sync::{Arc, Mutex};

    let cfg = cfg_from_args(args)?;
    apply_threads(&cfg);
    let requests: usize = flag_value(args, "--requests").unwrap_or("400").parse()?;
    let workers: usize = flag_value(args, "--workers").unwrap_or("4").parse()?;
    let max_batch: usize = flag_value(args, "--batch").unwrap_or("64").parse()?;
    let refreshes: usize = flag_value(args, "--refresh").unwrap_or("1").parse()?;
    let resume = args.iter().any(|a| a == "--resume");
    // parse the membership schedule up front so a typo fails before the
    // pipeline runs
    let membership = flag_value(args, "--membership-schedule")
        .map(|s| {
            crate::cluster::membership::parse_schedule(s)
                .map_err(|e| anyhow::anyhow!("--membership-schedule: {}", e))
                .map(|evs| (s, evs))
        })
        .transpose()?;
    if let Some((s, evs)) = &membership {
        anyhow::ensure!(!evs.is_empty(), "--membership-schedule '{}' names no events", s);
    }
    anyhow::ensure!(requests > 0, "--requests must be > 0");
    anyhow::ensure!(workers > 0, "--workers must be > 0");
    anyhow::ensure!(max_batch > 0, "--batch must be > 0");

    println!(
        "deal serve: dataset={} scale={} machines={} backend={} workers={} max_batch={}",
        cfg.dataset.name, cfg.dataset.scale, cfg.cluster.machines, cfg.exec.backend, workers, max_batch,
    );

    // ---- epoch 0: refresh the table through the inference pipeline,
    // or rebuild it from the durable store (`--resume`)
    let spill_budget = cfg.storage.budget_bytes;
    let store_dir = crate::storage::storage_dir();
    let pipeline = Pipeline::new(cfg.clone());
    let (report, durable) = if resume {
        let dir = store_dir.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "--resume requires a storage directory (--storage-dir, storage.dir, or DEAL_STORAGE_DIR)"
            )
        })?;
        anyhow::ensure!(DurableStore::exists(&dir), "--resume: no durable store in {:?}", dir);
        let (report, store, rec) = pipeline.warm_restart(&dir)?;
        println!(
            "warm restart from {:?}: gen {} watermark {} epoch {} ({} wal records replayed{}, sim {})",
            dir,
            store.generation(),
            rec.watermark,
            rec.epoch,
            rec.records_replayed,
            if rec.trimmed_at.is_some() { ", torn tail trimmed" } else { "" },
            human_secs(rec.sim_secs),
        );
        (report, Some((store, rec.epoch)))
    } else {
        let report = pipeline.run()?;
        match &store_dir {
            Some(dir) => {
                let emb = report
                    .embeddings
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("pipeline kept no embeddings"))?;
                let store =
                    DurableStore::create(dir, cfg.exec.seed, emb, DurableOptions::default())?;
                println!("durable store created in {:?} (gen 0, epoch 0)", dir);
                (report, Some((store, 0)))
            }
            None => (report, None),
        }
    };
    let start_epoch = durable.as_ref().map_or(0, |(_, e)| *e);
    let durable: Option<Arc<Mutex<DurableStore>>> =
        durable.map(|(s, _)| Arc::new(Mutex::new(s)));
    let embeddings = report
        .embeddings
        .clone()
        .ok_or_else(|| anyhow::anyhow!("pipeline kept no embeddings"))?;
    // spill mode: the serving epochs live on the paged tier under the
    // storage budget instead of doubling RAM across refreshes
    let table = if spill_budget > 0 {
        crate::serve::ShardedTable::from_inference_plan_spilled(
            &report.plan,
            &embeddings,
            start_epoch,
            spill_budget,
        )?
    } else {
        crate::serve::ShardedTable::from_inference_plan(&report.plan, &embeddings, start_epoch)
    };
    println!(
        "{} {} × {} embeddings into {} shards{} at epoch {} (sim {})",
        if resume { "recovered" } else { "refreshed" },
        table.n_nodes(),
        table.dim(),
        table.num_shards(),
        if table.is_spilled() { " [spilled]" } else { "" },
        start_epoch,
        human_secs(report.stages.total()),
    );
    let cell = Arc::new(TableCell::new(table));
    let backend = backend_from_config(&cfg.exec.backend, &cfg.artifacts_dir())?;

    // ---- synthetic workload: 3/4 Embed(32), 1/4 Similar(4, k=10)
    let n = embeddings.rows;
    let mut rng = Rng::new(cfg.exec.seed ^ 0x5E55);
    let reqs = synthetic_workload(&mut rng, n, requests, false);

    // ---- sequential single-copy baseline
    let emb_for_membership = membership.as_ref().map(|_| embeddings.clone());
    let server = EmbeddingServer::new(embeddings);
    let base = serve_workload(&server, &reqs, backend.as_ref())?;
    println!(
        "sequential baseline : {} req | p50 {} | p99 {} | {:.0} req/s",
        base.requests,
        human_secs(base.latency.p50),
        human_secs(base.latency.p99),
        base.throughput,
    );

    // ---- batched sharded pool, with mid-load refresh swaps
    let opts =
        PoolOpts { workers, queue_capacity: requests, max_batch, ..PoolOpts::default() };
    let pool = ServePool::spawn(Arc::clone(&cell), Arc::clone(&backend), opts);
    let mut refresher = Refresher::new(pipeline);
    if spill_budget > 0 {
        refresher = refresher.with_spill(spill_budget);
    }
    if let Some(store) = &durable {
        refresher = refresher.with_durable(Arc::clone(store));
    }
    let (pooled, refresh_reports) = std::thread::scope(|scope| {
        let handle = (refreshes > 0).then(|| {
            let cell = Arc::clone(&cell);
            let refresher = &refresher;
            scope.spawn(move || {
                (0..refreshes).map(|_| refresher.refresh(&cell)).collect::<Vec<_>>()
            })
        });
        let pooled = serve_workload_pooled(&pool, &reqs);
        let reports = handle.map(|h| h.join().expect("refresher panicked")).unwrap_or_default();
        (pooled, reports)
    });
    let (_responses, stats) = pooled?;
    println!(
        "sharded batched pool: {} req | p50 {} | p99 {} | {:.0} req/s  ({:.2}x)",
        stats.requests,
        human_secs(stats.latency.p50),
        human_secs(stats.latency.p99),
        stats.throughput,
        stats.throughput / base.throughput.max(1e-12),
    );
    for rep in refresh_reports {
        let rep = rep?;
        println!(
            "refresh swap → epoch {} ({} × {}, sim {}, {} over the wire) with zero dropped requests",
            rep.epoch,
            rep.nodes,
            rep.dim,
            human_secs(rep.sim_secs),
            human_bytes(rep.net_bytes),
        );
    }
    let final_stats = pool.shutdown();
    println!(
        "pool totals: served={} rejected={} failed={} batches={} max_batch={} coalesced_similar={}",
        final_stats.served,
        final_stats.rejected,
        final_stats.failed,
        final_stats.batches,
        final_stats.max_batch_seen,
        final_stats.coalesced_similar,
    );
    if spill_budget > 0 {
        let t = cell.load();
        let c = t.storage_counters();
        println!(
            "spill tier: {} resident of {} table bytes (budget {}) | faults={} evictions={} spilled={}",
            human_bytes(t.resident_bytes()),
            human_bytes(t.nbytes()),
            human_bytes(spill_budget),
            c.page_faults,
            c.evictions,
            human_bytes(c.spill_bytes_written + c.spill_bytes_read),
        );
    }
    if let Some(store) = &durable {
        let s = store.lock().expect("durable store lock poisoned");
        let c = s.counters();
        println!(
            "durable store: gen {} watermark {} epoch {} | wal {} | checkpoints {} | recoveries {}",
            s.generation(),
            s.watermark(),
            s.last_epoch(),
            human_bytes(c.wal_bytes),
            c.checkpoints,
            c.recoveries,
        );
    }
    anyhow::ensure!(final_stats.failed == 0, "{} requests failed", final_stats.failed);

    // ---- elastic membership phase: re-host the table on a simulated
    // cluster, walk the schedule, and prove serving stays bit-identical
    if let Some((sched, events)) = membership {
        use crate::cluster::membership::{ElasticCluster, ElasticOpts};

        let emb = emb_for_membership.expect("embeddings kept for membership phase");
        let world = cfg.cluster.machines;
        let opts = ElasticOpts {
            net: cfg.net(),
            seed: cfg.exec.seed,
            durable_root: store_dir.as_ref().map(|d| d.join("membership")),
            ..ElasticOpts::default()
        };
        let mut cluster = ElasticCluster::new(&emb, world, opts)?;
        println!(
            "\nmembership: world {} | schedule {} | durable shards {}",
            world,
            sched,
            if store_dir.is_some() { "on" } else { "off" },
        );
        let mpool = ServePool::spawn(
            cluster.cell(),
            Arc::clone(&backend),
            PoolOpts { workers, queue_capacity: requests, max_batch, ..PoolOpts::default() },
        );
        let mut mrng = Rng::new(cfg.exec.seed ^ 0x3E3B);
        let mreqs = synthetic_workload(&mut mrng, emb.rows, requests.min(128), false);
        let (base_resp, _) = serve_workload_pooled(&mpool, &mreqs)?;
        let base_digests: Vec<u64> = base_resp.iter().map(response_digest).collect();
        for ev in events {
            let stats = cluster.apply(ev)?;
            println!(
                "  {} → epoch {} | world {} | moved {} rows ({} on the wire, {} msgs) | recovered {} rows{} | sim {}",
                stats.event,
                stats.epoch,
                stats.world_after,
                stats.rows_moved,
                human_bytes(stats.bytes_on_wire),
                stats.msgs,
                stats.rows_recovered,
                if stats.recovered_from_durable { " [durable]" } else { "" },
                human_secs(stats.sim_secs),
            );
            let (resp, _) = serve_workload_pooled(&mpool, &mreqs)?;
            let digests: Vec<u64> = resp.iter().map(response_digest).collect();
            anyhow::ensure!(
                digests == base_digests,
                "serving responses changed across membership epoch {}",
                cluster.epoch(),
            );
        }
        cluster.verify_against(&emb)?;
        mpool.shutdown();
        println!(
            "  responses bit-identical across {} membership epochs; table matches the reference",
            cluster.history().len(),
        );
    }
    Ok(())
}

fn cmd_stream(args: &[String]) -> Result<()> {
    use crate::coordinator::delta::DeltaState;
    use crate::serve::{refresh_delta, ShardedTable, TableCell};
    use crate::util::rng::Rng;

    let cfg = cfg_from_args(args)?;
    apply_threads(&cfg);
    let batches: usize = flag_value(args, "--batches").unwrap_or("5").parse()?;
    let churn: f64 = flag_value(args, "--churn").unwrap_or("0.01").parse()?;
    let feat_churn: f64 = flag_value(args, "--feat-churn").unwrap_or("0").parse()?;
    let verify = args.iter().any(|a| a == "--verify");
    anyhow::ensure!(batches > 0, "--batches must be > 0");
    anyhow::ensure!(churn >= 0.0 && feat_churn >= 0.0, "churn rates must be >= 0");

    println!(
        "deal stream: dataset={} scale={} machines={} (P×M = {:?}) model={} fanout={} | {} batches at {:.2}% edge churn, {:.2}% feature churn",
        cfg.dataset.name,
        cfg.dataset.scale,
        cfg.cluster.machines,
        cfg.parts()?,
        cfg.model.kind,
        cfg.model.fanout,
        batches,
        churn * 100.0,
        feat_churn * 100.0,
    );

    let mut state = DeltaState::init(cfg.clone())?;
    let table = ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0);
    println!(
        "baseline: {} nodes, {} edges → {} × {} table in {} shards",
        state.n_nodes(),
        state.n_edges(),
        table.n_nodes(),
        table.dim(),
        table.num_shards(),
    );
    let cell = TableCell::new(table);
    let mut rng = Rng::new(cfg.exec.seed ^ 0x57E4);
    for b in 0..batches {
        let half = (state.n_edges() as f64 * churn / 2.0).round() as usize;
        let feats = (state.n_nodes() as f64 * feat_churn).round() as usize;
        let batch = state.synth_batch(&mut rng, half, half, feats);
        let rep = refresh_delta(&mut state, &batch, &cell)?;
        println!(
            "batch {:>3} → epoch {} | ±{} edges, {} feat rows | dirty {} | frontier {:?} | patched {} rows | sim {} | wall {} | {} over the wire",
            b,
            rep.epoch,
            half,
            feats,
            rep.dirty_rows,
            rep.frontier,
            rep.updated_rows,
            human_secs(rep.sim_secs),
            human_secs(rep.wall_secs),
            human_bytes(rep.net_bytes),
        );
    }
    if verify {
        let tag = format!("stream-verify-{}", std::process::id());
        let report =
            Pipeline::with_dataset(cfg, &tag, state.edge_list(), state.features().clone()).run()?;
        let full = report.embeddings.expect("embeddings kept");
        let diff = full.max_abs_diff(state.embeddings());
        println!(
            "verify: full recompute over {} rows, max |delta - full| = {:.2e}",
            full.rows, diff
        );
        anyhow::ensure!(diff < 5e-3, "delta state diverged from full recompute: {}", diff);
        println!("verify: incremental state matches the full recompute");
    }
    Ok(())
}

fn cmd_temporal(args: &[String]) -> Result<()> {
    use crate::runtime::backend_from_config;
    use crate::serve::response_digest;
    use crate::temporal::{TemporalEngine, TemporalOpts};

    let cfg = cfg_from_args(args)?;
    apply_threads(&cfg);
    let epochs: u64 = flag_value(args, "--epochs").unwrap_or("4").parse()?;
    let snapshot_every: u64 = flag_value(args, "--snapshot-every").unwrap_or("8").parse()?;
    let retain: usize = flag_value(args, "--retain").unwrap_or("4").parse()?;
    let churn: f64 = flag_value(args, "--churn").unwrap_or("0.01").parse()?;
    let feat_churn: f64 = flag_value(args, "--feat-churn").unwrap_or("0").parse()?;
    let probes: usize = flag_value(args, "--probes").unwrap_or("16").parse()?;
    let at: Option<u64> = flag_value(args, "--at").map(|v| v.parse()).transpose()?;
    let resume = args.iter().any(|a| a == "--resume");
    let verify = args.iter().any(|a| a == "--verify");
    anyhow::ensure!(snapshot_every > 0, "--snapshot-every must be > 0");
    anyhow::ensure!(churn >= 0.0 && feat_churn >= 0.0, "churn rates must be >= 0");

    let opts = TemporalOpts {
        snapshot_every,
        retain,
        durable_dir: crate::storage::storage_dir(),
    };
    println!(
        "deal temporal: dataset={} scale={} machines={} (P×M = {:?}) model={} | {} epochs × {} ticks, retain {}, durable {}",
        cfg.dataset.name,
        cfg.dataset.scale,
        cfg.cluster.machines,
        cfg.parts()?,
        cfg.model.kind,
        epochs,
        snapshot_every,
        retain,
        if opts.durable_dir.is_some() { "on" } else { "off" },
    );

    let mut engine = if resume {
        let e = TemporalEngine::resume(cfg.clone(), &opts)?;
        println!(
            "resumed from journal: epoch {} (clock {}), retained epochs {:?}",
            e.epoch(),
            e.clock(),
            e.retained_epochs(),
        );
        e
    } else {
        TemporalEngine::new(cfg.clone(), &opts)?
    };
    println!(
        "baseline: {} nodes, {} edges at epoch {}",
        engine.state().n_nodes(),
        engine.state().n_edges(),
        engine.epoch(),
    );

    let target = engine.epoch() + epochs;
    while engine.epoch() < target {
        let half = (engine.state().n_edges() as f64 * churn / 2.0).round() as usize;
        let feats = (engine.state().n_nodes() as f64 * feat_churn).round() as usize;
        let events = engine.synth_events(half, half, feats);
        engine.ingest(&events)?;
        let sealed = engine.advance_to((engine.epoch() + 1) * snapshot_every)?;
        for rep in &sealed {
            println!(
                "epoch {:>3} @ tick {:>6} | {:>5} events | {:>6} rows updated | digest {:#018x} | sim {} | wall {}",
                rep.epoch,
                rep.seal_tick,
                rep.events,
                rep.updated_rows,
                rep.digest,
                human_secs(rep.sim_secs),
                human_secs(rep.wall_secs),
            );
        }
    }
    println!("retained epochs: {:?}", engine.retained_epochs());

    if let Some(epoch) = at {
        let backend = backend_from_config(&cfg.exec.backend, &cfg.artifacts_dir())?;
        let reqs =
            crate::traffic::temporal_probe(cfg.exec.seed, engine.state().n_nodes(), probes);
        let responses = engine.serve_at(epoch, backend, &reqs)?;
        let mut digest = 0xcbf29ce484222325u64;
        for r in &responses {
            digest = digest.rotate_left(17) ^ response_digest(r);
        }
        println!(
            "time travel: served {} probes as of epoch {} | combined digest {:#018x}",
            responses.len(),
            epoch,
            digest,
        );
    }

    if verify {
        let snap = engine.snapshot_at(engine.epoch())?.to_full();
        let cold = engine.cold_oracle()?;
        anyhow::ensure!(
            snap == cold,
            "latest snapshot is not bit-identical to the cold full-graph recompute"
        );
        println!(
            "verify: epoch {} snapshot is bit-identical to a cold full recompute of {} rows",
            engine.epoch(),
            cold.rows,
        );
    }
    Ok(())
}

/// Build the trace generator's config from the deal config: the
/// `traffic.*` section plus `exec.seed` as the master seed and the live
/// table's node count as the id universe. Trace-shape details without a
/// config key (burst window length, ids per request, churn batch sizes)
/// keep `TraceConfig`'s defaults.
fn trace_config_from(cfg: &DealConfig, n_nodes: usize) -> crate::traffic::TraceConfig {
    crate::traffic::TraceConfig {
        seed: cfg.exec.seed,
        n_nodes,
        requests: cfg.traffic.requests,
        base_rate: cfg.traffic.rate,
        zipf_s: cfg.traffic.zipf_s,
        diurnal_amplitude: cfg.traffic.diurnal,
        burst_factor: cfg.traffic.burst,
        similar_fraction: cfg.traffic.similar_frac,
        churn_batches: cfg.traffic.churn_batches,
        ..crate::traffic::TraceConfig::default()
    }
}

fn cmd_traffic(args: &[String]) -> Result<()> {
    use crate::coordinator::delta::DeltaState;
    use crate::runtime::backend_from_config;
    use crate::serve::{BatchPolicy, PoolOpts, ServePool, ShardedTable, TableCell};
    use crate::traffic::{churn_into_cell, replay, ReplayMode, ReplayOpts, Trace};
    use std::sync::Arc;

    let mut cfg = cfg_from_args(args)?;
    apply_threads(&cfg);
    if let Some(v) = flag_value(args, "--requests") {
        cfg.traffic.requests = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--rate") {
        cfg.traffic.rate = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--policy") {
        cfg.traffic.policy = v.into();
    }
    if let Some(v) = flag_value(args, "--speed") {
        cfg.traffic.speed = v.parse()?;
    }
    let workers: usize = flag_value(args, "--workers").unwrap_or("4").parse()?;
    let queue: usize = flag_value(args, "--queue").unwrap_or("1024").parse()?;
    let sweep = args.iter().any(|a| a == "--sweep");
    anyhow::ensure!(cfg.traffic.requests > 0, "traffic.requests must be > 0");
    anyhow::ensure!(cfg.traffic.speed > 0.0, "traffic.speed must be > 0");
    // validate early, before the pipeline runs
    let policy = BatchPolicy::parse(&cfg.traffic.policy)?;

    println!(
        "deal traffic: dataset={} scale={} machines={} backend={} workers={} queue={} policy={}",
        cfg.dataset.name,
        cfg.dataset.scale,
        cfg.cluster.machines,
        cfg.exec.backend,
        workers,
        queue,
        policy.name(),
    );

    // Baseline state: the trace's churn events mutate it mid-replay.
    let mut state = DeltaState::init(cfg.clone())?;
    let n = state.n_nodes();
    let trace = match flag_value(args, "--trace-in") {
        Some(p) => {
            let t = Trace::load(std::path::Path::new(p))?;
            anyhow::ensure!(
                t.config.n_nodes == n,
                "trace was generated for {} nodes but the table has {}",
                t.config.n_nodes,
                n
            );
            t
        }
        None => Trace::generate(&trace_config_from(&cfg, n)),
    };
    if let Some(p) = flag_value(args, "--trace-out") {
        trace.save(std::path::Path::new(p))?;
        println!("wrote trace artifact to {}", p);
    }
    println!(
        "trace: {} requests + {} churn events over {:.2} simulated secs (zipf s={}, burst ×{})",
        trace.n_requests(),
        trace.n_churn(),
        trace.duration_secs(),
        trace.config.zipf_s,
        trace.config.burst_factor,
    );
    let backend = backend_from_config(&cfg.exec.backend, &cfg.artifacts_dir())?;

    if sweep {
        // Parity sweep: the same trace, sequenced, under every policy —
        // responses must be bit-identical (digest-equal) across them.
        let mut baseline: Option<Vec<u64>> = None;
        for spec in ["depth", "deadline:200", "size:256"] {
            let policy = BatchPolicy::parse(spec)?;
            // fresh deterministic state per policy: churn mutates it
            let mut st = DeltaState::init(cfg.clone())?;
            let table = ShardedTable::from_inference_plan(st.plan(), st.embeddings(), 0);
            let cell = Arc::new(TableCell::new(table));
            let pool = ServePool::spawn(
                Arc::clone(&cell),
                Arc::clone(&backend),
                PoolOpts { workers, queue_capacity: queue, policy, ..PoolOpts::default() },
            );
            let opts = ReplayOpts { mode: ReplayMode::Sequenced, ..ReplayOpts::default() };
            let rep = replay(&pool, &trace, &opts, churn_into_cell(&mut st, &cell))?;
            let stats = pool.shutdown();
            println!(
                "policy {:<12} served={} batches={} max_batch={} coalesced_similar={}",
                spec, stats.served, stats.batches, stats.max_batch_seen, stats.coalesced_similar,
            );
            match &baseline {
                None => baseline = Some(rep.digests),
                Some(b) => {
                    let diverged = b.iter().zip(&rep.digests).filter(|(x, y)| x != y).count();
                    anyhow::ensure!(
                        diverged == 0,
                        "policy {} changed {} of {} responses",
                        spec,
                        diverged,
                        b.len()
                    );
                }
            }
        }
        println!("parity: all policies produced bit-identical responses");
        return Ok(());
    }

    // Open-loop replay: inject on the trace schedule, never waiting for
    // completions; overload sheds at admission and shows up as rejects.
    let table = ShardedTable::from_inference_plan(state.plan(), state.embeddings(), 0);
    let cell = Arc::new(TableCell::new(table));
    let pool = ServePool::spawn(
        Arc::clone(&cell),
        backend,
        PoolOpts { workers, queue_capacity: queue, policy, ..PoolOpts::default() },
    );
    let opts = ReplayOpts {
        mode: ReplayMode::OpenLoop { speed: cfg.traffic.speed },
        ..ReplayOpts::default()
    };
    let rep = replay(&pool, &trace, &opts, churn_into_cell(&mut state, &cell))?;
    for c in &rep.stats.per_class {
        let (p50, p99, p999) = c
            .latency
            .as_ref()
            .map_or((0.0, 0.0, 0.0), |l| (l.p50, l.p99, l.p999));
        println!(
            "class {:<8} submitted={:<6} served={:<6} rejected={:<5} failed={:<3} p50 {} | p99 {} | p999 {}",
            c.class.name(),
            c.counters.submitted,
            c.counters.served,
            c.counters.rejected,
            c.counters.failed,
            human_secs(p50),
            human_secs(p99),
            human_secs(p999),
        );
    }
    println!(
        "goodput {:.0} resp/s | wall {} | max dispatch lag {} | churn epochs {:?}",
        rep.goodput,
        human_secs(rep.wall_secs),
        human_secs(rep.max_dispatch_lag_secs),
        rep.churn_epochs,
    );
    anyhow::ensure!(rep.stats.failed == 0, "{} requests failed", rep.stats.failed);
    Ok(())
}

/// Honor `--threads` on the config-less generator commands too.
fn apply_threads_flag(args: &[String]) -> Result<()> {
    if let Some(t) = flag_value(args, "--threads") {
        crate::runtime::par::set_threads(t.parse()?);
    }
    Ok(())
}

fn cmd_gen_dataset(args: &[String]) -> Result<()> {
    apply_threads_flag(args)?;
    let name = flag_value(args, "--name").ok_or_else(|| anyhow::anyhow!("--name required"))?;
    let scale: f64 = flag_value(args, "--scale").unwrap_or("1.0").parse()?;
    let out = PathBuf::from(
        flag_value(args, "--out").ok_or_else(|| anyhow::anyhow!("--out required"))?,
    );
    let ds = datasets::load(name, scale)?;
    ds.edges.write_binary(&out)?;
    println!(
        "wrote {} ({} nodes, {} edges, {})",
        out.display(),
        ds.edges.n_nodes,
        ds.edges.n_edges(),
        human_bytes(ds.edges.binary_size())
    );
    Ok(())
}

fn cmd_gen_labelled(args: &[String]) -> Result<()> {
    apply_threads_flag(args)?;
    let nodes: usize = flag_value(args, "--nodes").unwrap_or("4096").parse()?;
    let classes: usize = flag_value(args, "--classes").unwrap_or("8").parse()?;
    let degree: usize = flag_value(args, "--degree").unwrap_or("12").parse()?;
    let dim: usize = flag_value(args, "--dim").unwrap_or("32").parse()?;
    let seed: u64 = flag_value(args, "--seed").unwrap_or("42").parse()?;
    let out = PathBuf::from(
        flag_value(args, "--out").ok_or_else(|| anyhow::anyhow!("--out required"))?,
    );
    write_labelled(nodes, classes, degree, dim, seed, &out)
}

/// Generate and persist the labelled SBM study set (shared with the
/// python training script and the Table 6 bench).
pub fn write_labelled(
    nodes: usize,
    classes: usize,
    degree: usize,
    dim: usize,
    seed: u64,
    out: &std::path::Path,
) -> Result<()> {
    use std::io::Write;
    let ds = datasets::labelled_sbm(nodes, classes, degree, dim, 0.8, seed);
    std::fs::create_dir_all(out)?;
    ds.edges.write_binary(&out.join("edges.bin"))?;
    crate::runtime::save_weights(&out.join("features.bin"), &[ds.features.clone()])?;
    let mut f = std::io::BufWriter::new(std::fs::File::create(out.join("labels.bin"))?);
    f.write_all(&(ds.labels.len() as u64).to_le_bytes())?;
    f.write_all(&(ds.n_classes as u64).to_le_bytes())?;
    for &l in &ds.labels {
        f.write_all(&l.to_le_bytes())?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(out.join("train_mask.bin"))?);
    f.write_all(&(ds.train_mask.len() as u64).to_le_bytes())?;
    for &m in &ds.train_mask {
        f.write_all(&[u8::from(m)])?;
    }
    println!(
        "wrote labelled set to {} ({} nodes, {} classes, {} edges, dim {})",
        out.display(),
        nodes,
        classes,
        ds.edges.n_edges(),
        dim
    );
    Ok(())
}

/// Load the labelled study set written by `write_labelled`.
pub fn read_labelled(dir: &std::path::Path) -> Result<datasets::LabelledDataset> {
    use std::io::Read;
    let edges = crate::graph::EdgeList::read_binary(&dir.join("edges.bin"))?;
    let features = crate::runtime::load_weights(&dir.join("features.bin"))?
        .pop()
        .ok_or_else(|| anyhow::anyhow!("empty features.bin"))?;
    let mut f = std::fs::File::open(dir.join("labels.bin"))?;
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    f.read_exact(&mut b8)?;
    let n_classes = u64::from_le_bytes(b8) as usize;
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    let labels: Vec<u32> = buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let mut f = std::fs::File::open(dir.join("train_mask.bin"))?;
    f.read_exact(&mut b8)?;
    let nm = u64::from_le_bytes(b8) as usize;
    let mut mask = vec![0u8; nm];
    f.read_exact(&mut mask)?;
    Ok(datasets::LabelledDataset {
        edges,
        features,
        labels,
        n_classes,
        train_mask: mask.into_iter().map(|b| b != 0).collect(),
    })
}

fn cmd_datasets() -> Result<()> {
    println!("{:<14} {:>10} {:>8} {:>6}  stands in for", "name", "nodes", "avg deg", "dim");
    for s in datasets::REGISTRY {
        println!(
            "{:<14} {:>10} {:>8} {:>6}  {}",
            s.name,
            1usize << s.scale_log2,
            s.avg_degree,
            s.feature_dim,
            s.stands_in_for
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_value_extracts() {
        let args: Vec<String> = ["--name", "x", "--scale", "0.5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(flag_value(&args, "--name"), Some("x"));
        assert_eq!(flag_value(&args, "--scale"), Some("0.5"));
        assert_eq!(flag_value(&args, "--out"), None);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&["bogus".into()]).is_err());
        assert!(dispatch(&["help".into()]).is_ok());
        assert!(dispatch(&[]).is_ok());
    }

    #[test]
    fn serve_smoke() {
        // tiny end-to-end: refresh a 256-node table, serve 40 requests
        // through the pool with one mid-load refresh swap
        let args: Vec<String> = [
            "serve",
            "--requests",
            "40",
            "--workers",
            "2",
            "--refresh",
            "1",
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        // thread-local pins: this test's effective storage config stays
        // resident and ephemeral even if a parallel test writes the
        // process globals or CI exports DEAL_STORAGE_DIR (a shared store
        // dir across concurrent serves would clobber ckpt files)
        let r = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(0, || dispatch(&args))
        });
        // undo the process-global knob writes (`apply_threads`) so the
        // env-driven storage configuration of parallel tests survives
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        r.unwrap();
    }

    #[test]
    fn serve_spilled_smoke() {
        // spill mode: tiny storage budget → inference tiles page out and
        // serving epochs stage on disk; must still serve every request
        let args: Vec<String> = [
            "serve",
            "--requests",
            "30",
            "--workers",
            "2",
            "--refresh",
            "1",
            "--mem-budget",
            "16k",
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        // thread-local pins: the spilled run keeps its 16 KiB budget and
        // an ephemeral store even if a parallel CLI test writes the
        // process globals mid-flight (the paged tiers are guaranteed
        // active, never silently vacuous)
        let r = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(16 << 10, || dispatch(&args))
        });
        // reset the process-global knobs so parallel lib tests keep their
        // own (thread-local / env) storage configuration
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        r.unwrap();
    }

    #[test]
    fn serve_resume_smoke() {
        // durable round trip: a cold serve journals into --storage-dir,
        // then `serve --resume` rebuilds the table from disk (no
        // pipeline run) and keeps serving + journaling on top of it
        let dir = std::env::temp_dir()
            .join(format!("deal-serve-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let base: Vec<String> = [
            "serve",
            "--requests",
            "30",
            "--workers",
            "2",
            "--refresh",
            "1",
            "--storage-dir",
            &dir.display().to_string(),
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut resume = base.clone();
        resume.push("--resume".into());
        // the thread-local pin beats any CI-wide DEAL_STORAGE_DIR, so
        // this test's store is private to it
        let r = crate::storage::with_storage_dir(&dir.display().to_string(), || {
            crate::storage::with_mem_budget(0, || {
                dispatch(&base)?;
                anyhow::ensure!(
                    crate::storage::DurableStore::exists(&dir),
                    "cold serve left no durable store in {:?}",
                    dir
                );
                dispatch(&resume)
            })
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        let _ = std::fs::remove_dir_all(&dir);
        r.unwrap();
        // --resume without any storage directory is a hard error
        let bare: Vec<String> =
            ["serve", "--resume"].iter().map(|s| s.to_string()).collect();
        let err = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(0, || dispatch(&bare))
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        assert!(err.is_err(), "--resume without a dir must fail");
    }

    #[test]
    fn serve_membership_smoke() {
        // elastic phase: refresh a 256-node table, then walk a
        // leave/join/kill schedule; the command hard-asserts responses
        // stay bit-identical across every membership epoch
        let args: Vec<String> = [
            "serve",
            "--requests",
            "30",
            "--workers",
            "2",
            "--refresh",
            "0",
            "--membership-schedule",
            "leave:2,join:2,kill:1",
            "--set",
            "cluster.machines=3",
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(0, || dispatch(&args))
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        r.unwrap();
        // a malformed schedule fails before the pipeline runs
        let bad: Vec<String> = ["serve", "--membership-schedule", "explode:1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(dispatch(&bad).is_err(), "bad schedule must be rejected up front");
    }

    #[test]
    fn stream_smoke() {
        // tiny end-to-end: 2 delta epochs over a 256-node graph, then a
        // full-recompute parity check (--verify asserts it)
        let args: Vec<String> = [
            "stream",
            "--batches",
            "2",
            "--churn",
            "0.005",
            "--feat-churn",
            "0.004",
            "--verify",
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(0, || dispatch(&args))
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        r.unwrap();
    }

    #[test]
    fn temporal_smoke() {
        // tiny end-to-end: 3 epoch windows over a 256-node graph, a
        // time-travel serve at epoch 1, and the cold-recompute
        // bit-identity check (--verify hard-asserts it)
        let args: Vec<String> = [
            "temporal",
            "--epochs",
            "3",
            "--snapshot-every",
            "4",
            "--retain",
            "4",
            "--churn",
            "0.01",
            "--feat-churn",
            "0.004",
            "--at",
            "1",
            "--probes",
            "8",
            "--verify",
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(0, || dispatch(&args))
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        r.unwrap();
    }

    #[test]
    fn temporal_resume_smoke() {
        // durable round trip: seal 2 epochs into --storage-dir, then
        // `temporal --resume` rebuilds the epoch index from the journal
        // and seals 1 more on top (bit-identity still asserted)
        let dir = std::env::temp_dir()
            .join(format!("deal-temporal-cli-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let base: Vec<String> = [
            "temporal",
            "--epochs",
            "2",
            "--snapshot-every",
            "4",
            "--retain",
            "2",
            "--churn",
            "0.01",
            "--verify",
            "--storage-dir",
            &dir.display().to_string(),
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let resume: Vec<String> = [
            "temporal",
            "--epochs",
            "1",
            "--snapshot-every",
            "4",
            "--retain",
            "2",
            "--churn",
            "0.01",
            "--verify",
            "--resume",
            // serve an epoch that retention evicted: only reachable
            // through the durable journal
            "--at",
            "0",
            "--probes",
            "6",
            "--storage-dir",
            &dir.display().to_string(),
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let r = crate::storage::with_storage_dir(&dir.display().to_string(), || {
            crate::storage::with_mem_budget(0, || {
                dispatch(&base)?;
                dispatch(&resume)
            })
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        let _ = std::fs::remove_dir_all(&dir);
        r.unwrap();
    }

    #[test]
    fn traffic_smoke() {
        // tiny end-to-end: generate a 60-request trace with one churn
        // batch over a 256-node table, write the artifact, replay it
        // open-loop, then replay the saved trace in a 3-policy parity
        // sweep (bit-identical responses asserted by the command)
        let trace_path =
            std::env::temp_dir().join(format!("deal-traffic-{}.trace", std::process::id()));
        let base: Vec<String> = [
            "traffic",
            "--requests",
            "60",
            "--speed",
            "200",
            "--workers",
            "2",
            "--set",
            "traffic.churn_batches=1",
            "--set",
            "dataset.scale=0.00390625",
            "--set",
            "model.layers=2",
            "--set",
            "model.fanout=5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut open_loop = base.clone();
        open_loop.extend(["--trace-out".into(), trace_path.display().to_string()]);
        let mut sweep = base;
        sweep.extend([
            "--trace-in".into(),
            trace_path.display().to_string(),
            "--sweep".into(),
        ]);
        let r = crate::storage::with_storage_dir("", || {
            crate::storage::with_mem_budget(0, || {
                dispatch(&open_loop)?;
                dispatch(&sweep)
            })
        });
        crate::storage::set_mem_budget(u64::MAX);
        crate::storage::set_page_rows(usize::MAX);
        crate::storage::set_storage_dir("");
        let _ = std::fs::remove_file(&trace_path);
        r.unwrap();
    }

    #[test]
    fn labelled_roundtrip() {
        let dir = std::env::temp_dir().join(format!("deal-lab-{}", std::process::id()));
        write_labelled(200, 4, 6, 8, 7, &dir).unwrap();
        let ds = read_labelled(&dir).unwrap();
        assert_eq!(ds.labels.len(), 200);
        assert_eq!(ds.n_classes, 4);
        assert_eq!(ds.features.rows, 200);
        assert_eq!(ds.features.cols, 8);
        assert_eq!(ds.train_mask.len(), 200);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
