//! Elastic cluster membership (DESIGN.md §Membership).
//!
//! The paper's deployment assumes a fixed 16-machine world; ROADMAP open
//! item 1 asks for the production-shaped counterpart: ranks that join,
//! leave, or die under load without changing a single served bit. The
//! design is Sui-style epoch-fenced reconfiguration:
//!
//! - [`Membership`] is the driver-side state machine. Each rank is
//!   `Joining`, `Active`, `Draining`, or `Dead`; every transition
//!   consumes one **membership epoch**. Transitions are two-phase:
//!   `begin` bumps the epoch and marks the subject, `commit` finalizes,
//!   `abort` reverts the subject but *never rewinds the epoch* — an
//!   epoch, once consumed, fences out every message stamped with it.
//! - [`fence`] is the rejection point: migration traffic carries its
//!   epoch in an in-band header and a receiver drops a mismatched epoch
//!   deterministically ([`StaleEpoch`]) before touching the payload.
//! - [`ElasticCluster`] owns the serving table across transitions. Re-
//!   sharding is **incremental**: `PartitionPlan::band_diff` yields only
//!   the row bands whose owner changes, and only those rows ride the
//!   PR 4 chunked streams. The new table is published through the
//!   double-buffered [`TableCell`] (`serve/refresh.rs`), so in-flight
//!   reads keep their epoch snapshot — the same swap discipline as a
//!   daily refresh.
//! - A **killed** rank's band is rebuilt without recompute: each rank
//!   checkpoints its band in a per-shard [`DurableStore`]
//!   (`storage/durable`), and the kill transition replays that store's
//!   WAL + checkpoint (`DurableStore::open`). Recovered rows are
//!   bit-verified against the last published epoch before reuse; a
//!   stale or missing store falls back to re-shipping the rows from the
//!   published snapshot held by a surviving peer. A later `join` of the
//!   same rank reuses its grave the same way (rejoin-from-durable).
//!
//! **Why values never depend on the schedule:** embeddings are computed
//! once and only *placed*; every transition moves, recovers, or keeps
//! exact row copies (verified by bit comparison on the durable path),
//! and the serving swap is atomic. Simulated time, byte counts, and
//! ownership change with the schedule — the served bits cannot. The
//! crash-point sweep in `tests/membership.rs` enforces this at every
//! armed transport boundary (`net::fault`) of every transition.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cluster::{Cluster, NetConfig, Payload, Tag};
use crate::partition::PartitionPlan;
use crate::serve::{ShardedTable, TableCell};
use crate::storage::durable::{shard_dir, DurableOptions, DurableStore};
use crate::tensor::Matrix;
use crate::Result;

/// Tag phase of epoch-fence headers on the migration wire.
const FENCE_PHASE: u32 = 0x004D_454D; // "MEM"
/// Tag phase of migrated band data.
const DATA_PHASE: u32 = 0x004D_4544; // "MED"

/// Lifecycle of one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankState {
    /// Mid-join: receiving its band; serves nothing yet.
    Joining,
    /// Full member: owns a band, serves traffic.
    Active,
    /// Mid-leave: shipping its band out; still alive.
    Draining,
    /// Not a member (never joined, left, or killed).
    Dead,
}

/// One reconfiguration request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipEvent {
    /// Rank enters (or re-enters) the cluster.
    Join { rank: usize },
    /// Rank leaves gracefully: it ships its own band before going dead.
    Leave { rank: usize },
    /// Rank dies without warning: its band is rebuilt from its durable
    /// store (or re-shipped from the published snapshot by a peer).
    Kill { rank: usize },
}

impl MembershipEvent {
    /// The rank the event is about.
    pub fn rank(&self) -> usize {
        match *self {
            MembershipEvent::Join { rank }
            | MembershipEvent::Leave { rank }
            | MembershipEvent::Kill { rank } => rank,
        }
    }

    /// Schedule-token spelling of the action.
    pub fn action(&self) -> &'static str {
        match self {
            MembershipEvent::Join { .. } => "join",
            MembershipEvent::Leave { .. } => "leave",
            MembershipEvent::Kill { .. } => "kill",
        }
    }
}

impl std::fmt::Display for MembershipEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.action(), self.rank())
    }
}

/// Parse a `"join:4,kill:2,leave:0"` schedule (the CLI's
/// `--membership-schedule` format; whitespace around tokens is ignored).
pub fn parse_schedule(s: &str) -> std::result::Result<Vec<MembershipEvent>, String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|tok| {
            let (kind, rank) = tok
                .split_once(':')
                .ok_or_else(|| format!("bad membership event '{}' (want action:rank)", tok))?;
            let rank: usize = rank
                .trim()
                .parse()
                .map_err(|_| format!("bad rank in membership event '{}'", tok))?;
            match kind.trim() {
                "join" => Ok(MembershipEvent::Join { rank }),
                "leave" => Ok(MembershipEvent::Leave { rank }),
                "kill" => Ok(MembershipEvent::Kill { rank }),
                other => Err(format!("unknown membership action '{}'", other)),
            }
        })
        .collect()
}

/// A message carried an epoch that is not the fence's — rejected before
/// its payload is looked at, deterministically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StaleEpoch {
    pub got: u64,
    pub want: u64,
}

impl std::fmt::Display for StaleEpoch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stale membership epoch {} (fence is at {})", self.got, self.want)
    }
}

impl std::error::Error for StaleEpoch {}

/// The fence check: traffic stamped `got` passes only a fence at exactly
/// the same epoch. Aborted transitions keep their epoch consumed, so
/// their traffic can never pass a later fence.
pub fn fence(got: u64, want: u64) -> std::result::Result<(), StaleEpoch> {
    if got == want {
        Ok(())
    } else {
        Err(StaleEpoch { got, want })
    }
}

/// Driver-side membership state machine: per-rank lifecycle plus the
/// monotone epoch counter every transition consumes.
#[derive(Clone, Debug)]
pub struct Membership {
    epoch: u64,
    states: Vec<RankState>,
    min_active: usize,
    /// In-flight transition: the event and the subject's prior state
    /// (restored by `abort`).
    pending: Option<(MembershipEvent, RankState)>,
}

impl Membership {
    /// A fixed world of `world` active ranks at epoch 0. `min_active` is
    /// the floor no leave/kill may shrink the cluster below.
    pub fn new(world: usize, min_active: usize) -> Membership {
        assert!(world >= 1, "empty cluster");
        assert!((1..=world).contains(&min_active), "bad active floor {}", min_active);
        Membership {
            epoch: 0,
            states: vec![RankState::Active; world],
            min_active,
            pending: None,
        }
    }

    /// Current membership epoch (bumped by every `begin`, kept by
    /// `abort`).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// State of `rank` (`Dead` for ranks never seen).
    pub fn state(&self, rank: usize) -> RankState {
        self.states.get(rank).copied().unwrap_or(RankState::Dead)
    }

    /// Ranks currently serving (Active), ascending.
    pub fn active(&self) -> Vec<usize> {
        self.ranks_in(|s| s == RankState::Active)
    }

    /// Ranks that own a band *after* the in-flight transition commits:
    /// Active plus Joining, minus Draining/Dead, ascending.
    pub fn target(&self) -> Vec<usize> {
        self.ranks_in(|s| matches!(s, RankState::Active | RankState::Joining))
    }

    fn ranks_in(&self, pred: impl Fn(RankState) -> bool) -> Vec<usize> {
        self.states
            .iter()
            .enumerate()
            .filter(|(_, &s)| pred(s))
            .map(|(r, _)| r)
            .collect()
    }

    fn n_active(&self) -> usize {
        self.states.iter().filter(|&&s| s == RankState::Active).count()
    }

    /// True while a transition is between `begin` and `commit`/`abort`.
    pub fn in_transition(&self) -> bool {
        self.pending.is_some()
    }

    /// Start a transition: validate, consume the next epoch, and mark the
    /// subject (`Joining`, `Draining`, or `Dead`). Returns the new epoch.
    pub fn begin(&mut self, ev: MembershipEvent) -> std::result::Result<u64, String> {
        if let Some((pending, _)) = &self.pending {
            return Err(format!("transition {} already in flight", pending));
        }
        let r = ev.rank();
        let prior;
        match ev {
            MembershipEvent::Join { .. } => {
                if r >= self.states.len() {
                    self.states.resize(r + 1, RankState::Dead);
                }
                prior = self.states[r];
                if prior != RankState::Dead {
                    return Err(format!("rank {} cannot join: already {:?}", r, prior));
                }
                self.states[r] = RankState::Joining;
            }
            MembershipEvent::Leave { .. } | MembershipEvent::Kill { .. } => {
                prior = self.state(r);
                if prior != RankState::Active {
                    return Err(format!("rank {} cannot {}: not active", r, ev.action()));
                }
                if self.n_active() - 1 < self.min_active {
                    return Err(format!(
                        "cannot {} rank {}: {} active ranks is the floor",
                        ev.action(),
                        r,
                        self.min_active
                    ));
                }
                self.states[r] = match ev {
                    MembershipEvent::Leave { .. } => RankState::Draining,
                    _ => RankState::Dead,
                };
            }
        }
        self.epoch += 1;
        self.pending = Some((ev, prior));
        Ok(self.epoch)
    }

    /// Finalize the in-flight transition: `Joining` becomes `Active`,
    /// `Draining` becomes `Dead`, a kill stays `Dead`.
    pub fn commit(&mut self) {
        let (ev, _) = self.pending.take().expect("no transition to commit");
        self.states[ev.rank()] = match ev {
            MembershipEvent::Join { .. } => RankState::Active,
            MembershipEvent::Leave { .. } | MembershipEvent::Kill { .. } => RankState::Dead,
        };
    }

    /// Cancel the in-flight transition: the subject reverts to its prior
    /// state but the epoch stays consumed — fences never rewind, so any
    /// traffic stamped with the aborted epoch is stale forever.
    pub fn abort(&mut self) {
        let (ev, prior) = self.pending.take().expect("no transition to abort");
        self.states[ev.rank()] = prior;
    }
}

/// How a transition moves the rows that change owner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationMode {
    /// Ship only `band_diff` segments; unchanged bands stay put and the
    /// durable tier substitutes for the wire where it can (default).
    Incremental,
    /// Naive baseline: every row of the new layout goes over the wire,
    /// durable recovery disabled — what `benches/membership_elastic.rs`
    /// compares against.
    FullReshard,
}

/// What one committed transition did (one entry per event in
/// [`ElasticCluster::history`]).
#[derive(Clone, Debug)]
pub struct MigrationStats {
    pub event: MembershipEvent,
    /// Membership epoch the transition was fenced at.
    pub epoch: u64,
    /// Serving epoch `TableCell::handoff` published the new table at.
    pub serving_epoch: u64,
    /// Band-owning ranks after the commit.
    pub world_after: usize,
    /// Rows shipped over the simulated wire.
    pub rows_moved: usize,
    /// Rows rebuilt from a per-shard durable store (never on the wire).
    pub rows_recovered: usize,
    /// Wire bytes of the migration (fence headers + chunked bands).
    pub bytes_on_wire: u64,
    /// Wire messages of the migration.
    pub msgs: u64,
    /// Simulated seconds: migration makespan plus durable replay I/O.
    pub sim_secs: f64,
    /// True when the durable path supplied at least one row.
    pub recovered_from_durable: bool,
}

/// Knobs for an [`ElasticCluster`].
#[derive(Clone, Debug)]
pub struct ElasticOpts {
    /// Link model for migration traffic.
    pub net: NetConfig,
    /// Cores per simulated machine.
    pub cores: f64,
    /// Seed stamped into per-shard durable stores.
    pub seed: u64,
    /// Floor the membership machine refuses to shrink below.
    pub min_active: usize,
    /// Root directory for per-shard durable stores (`shard_dir`); `None`
    /// disables the durable recovery path (kills rebuild from peers).
    pub durable_root: Option<PathBuf>,
}

impl Default for ElasticOpts {
    fn default() -> Self {
        ElasticOpts {
            // the paper's testbed link: 25 Gbps, 100 µs
            net: NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 },
            cores: 64.0,
            seed: 0,
            min_active: 1,
            durable_root: None,
        }
    }
}

/// A rank's band as recovered from its per-shard durable store.
struct LoadedShard {
    lo: usize,
    hi: usize,
    table: Matrix,
    sim_secs: f64,
}

impl LoadedShard {
    fn covers(&self, lo: usize, hi: usize) -> bool {
        self.lo <= lo && hi <= self.hi
    }
}

/// One row band changing hands over the wire.
struct WireMove {
    lo: usize,
    hi: usize,
    src: usize,
    dst: usize,
    data: Matrix,
}

/// The serving table under elastic membership: owns the band layout, the
/// per-rank primary copies, the per-shard durable stores, and the
/// [`TableCell`] swap point. [`ElasticCluster::apply`] runs one
/// epoch-fenced transition end to end.
pub struct ElasticCluster {
    membership: Membership,
    /// Current layout: one row band per owning rank (`p = |owners|`,
    /// `m = 1` — the serving shape).
    plan: PartitionPlan,
    /// Part index → rank id owning that band.
    owners: Vec<usize>,
    /// Rank id → its resident band (primary copy); `None` for non-members.
    shards: Vec<Option<Matrix>>,
    cell: Arc<TableCell>,
    opts: ElasticOpts,
    n_nodes: usize,
    dim: usize,
    history: Vec<MigrationStats>,
}

impl ElasticCluster {
    /// A fixed world of `world` active ranks serving `embeddings`, all at
    /// membership epoch 0. With a `durable_root`, every rank checkpoints
    /// its band immediately (the recovery source for later kills).
    pub fn new(embeddings: &Matrix, world: usize, opts: ElasticOpts) -> Result<ElasticCluster> {
        anyhow::ensure!(world >= 1, "empty cluster");
        anyhow::ensure!(
            world <= embeddings.rows,
            "{} ranks for {} table rows",
            world,
            embeddings.rows
        );
        let plan = PartitionPlan::new(embeddings.rows, embeddings.cols.max(1), world, 1);
        let shards: Vec<Option<Matrix>> = (0..world)
            .map(|p_idx| {
                let (lo, hi) = plan.node_range(p_idx);
                Some(embeddings.slice_rows(lo, hi))
            })
            .collect();
        let cell = Arc::new(TableCell::new(ShardedTable::from_full(embeddings, world, 0)));
        let ec = ElasticCluster {
            membership: Membership::new(world, opts.min_active),
            plan,
            owners: (0..world).collect(),
            shards,
            cell,
            opts,
            n_nodes: embeddings.rows,
            dim: embeddings.cols,
            history: Vec::new(),
        };
        for (p_idx, &rank) in ec.owners.iter().enumerate() {
            let (lo, hi) = ec.plan.node_range(p_idx);
            let band = ec.shards[rank].as_ref().expect("initial owner has a band");
            ec.persist_shard(rank, lo, hi, band)?;
        }
        Ok(ec)
    }

    /// Current membership epoch.
    pub fn epoch(&self) -> u64 {
        self.membership.epoch()
    }

    /// Serving epoch of the published table.
    pub fn serving_epoch(&self) -> u64 {
        self.cell.epoch()
    }

    /// The swap point, for wiring a `ServePool` over this cluster.
    pub fn cell(&self) -> Arc<TableCell> {
        Arc::clone(&self.cell)
    }

    /// Snapshot of the published serving table.
    pub fn table(&self) -> Arc<ShardedTable> {
        self.cell.load()
    }

    /// The membership state machine (read-only).
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// Current band layout.
    pub fn plan(&self) -> &PartitionPlan {
        &self.plan
    }

    /// Band-owning ranks, in part order.
    pub fn owners(&self) -> &[usize] {
        &self.owners
    }

    /// Committed transitions, oldest first.
    pub fn history(&self) -> &[MigrationStats] {
        &self.history
    }

    /// Hard bit-identity check of the published table against the
    /// fixed-world reference (the repo's determinism contract extended to
    /// membership schedules).
    pub fn verify_against(&self, reference: &Matrix) -> Result<()> {
        let full = self.cell.load().to_full();
        anyhow::ensure!(
            full.rows == reference.rows && full.cols == reference.cols,
            "served table is {}x{}, reference {}x{}",
            full.rows,
            full.cols,
            reference.rows,
            reference.cols
        );
        anyhow::ensure!(
            bits_equal(&full, reference),
            "served table diverged from the fixed-world reference"
        );
        Ok(())
    }

    /// Run one transition end to end: `begin` (epoch fence), migrate the
    /// changed bands, publish through the double-buffered cell, `commit`.
    /// On any migration failure — including an injected rank kill — the
    /// transition aborts: the old table keeps serving, the subject
    /// reverts, and the consumed epoch fences out the aborted traffic.
    pub fn apply(&mut self, ev: MembershipEvent) -> Result<MigrationStats> {
        self.apply_mode(ev, MigrationMode::Incremental)
    }

    /// [`ElasticCluster::apply`] with an explicit [`MigrationMode`] (the
    /// bench uses `FullReshard` as its naive baseline).
    pub fn apply_mode(&mut self, ev: MembershipEvent, mode: MigrationMode) -> Result<MigrationStats> {
        let epoch = self.membership.begin(ev).map_err(anyhow::Error::msg)?;
        match self.migrate(ev, epoch, mode) {
            Ok(stats) => {
                self.membership.commit();
                self.history.push(stats.clone());
                Ok(stats)
            }
            Err(e) => {
                self.membership.abort();
                Err(e)
            }
        }
    }

    /// The migration itself: plan the new layout, classify every band
    /// segment (keep / recover-from-durable / ship), run the epoch-fenced
    /// transfer on the simulated cluster, assemble, hand off, persist.
    fn migrate(
        &mut self,
        ev: MembershipEvent,
        epoch: u64,
        mode: MigrationMode,
    ) -> Result<MigrationStats> {
        let new_owners = self.membership.target();
        anyhow::ensure!(!new_owners.is_empty(), "no live ranks left");
        anyhow::ensure!(
            new_owners.len() <= self.n_nodes,
            "{} live ranks for {} table rows",
            new_owners.len(),
            self.n_nodes
        );
        let new_plan = self
            .plan
            .refactor_world(new_owners.len(), 1)
            .map_err(anyhow::Error::msg)?;
        let snapshot = self.cell.load();
        let dead = match ev {
            MembershipEvent::Kill { rank } => Some(rank),
            _ => None,
        };
        // The subject's durable band, if one is on disk: a killed rank's
        // grave, or a rejoiner's band from before it left.
        let subject_store = match mode {
            MigrationMode::Incremental => self.load_shard(ev.rank()),
            MigrationMode::FullReshard => None,
        };

        // Classify segments.
        let mut keeps: Vec<(usize, usize, usize)> = Vec::new(); // (lo, hi, rank)
        let mut recovered: Vec<(usize, Matrix)> = Vec::new(); // (lo, rows)
        let mut moves: Vec<WireMove> = Vec::new();
        let mut rows_recovered = 0usize;
        let mut snapshot_full: Option<Matrix> = None;
        for seg in self.plan.band_segments(&new_plan) {
            let from = self.owners[seg.old_part];
            let to = new_owners[seg.new_part];
            if from == to && mode == MigrationMode::Incremental {
                keeps.push((seg.lo, seg.hi, to));
                continue;
            }
            // Durable substitution: a killed primary's rows, or rows a
            // rejoiner already holds, come from the store — but only
            // after a bit-exact check against the last published epoch,
            // so a stale store can never smuggle in old values.
            let durable_applies = match (&subject_store, ev) {
                (Some(st), MembershipEvent::Kill { rank }) => {
                    from == rank && st.covers(seg.lo, seg.hi)
                }
                (Some(st), MembershipEvent::Join { rank }) => {
                    to == rank && st.covers(seg.lo, seg.hi)
                }
                _ => false,
            };
            if durable_applies {
                let st = subject_store.as_ref().unwrap();
                let cand = st.table.slice_rows(seg.lo - st.lo, seg.hi - st.lo);
                let truth = snapshot_full.get_or_insert_with(|| snapshot.to_full());
                if bits_equal(&cand, &truth.slice_rows(seg.lo, seg.hi)) {
                    rows_recovered += cand.rows;
                    recovered.push((seg.lo, cand));
                    continue;
                }
                // stale store — fall through to the wire
            }
            // Wire path. A live source ships its own band; a dead
            // source's rows are re-shipped from the published snapshot by
            // a surviving peer (the serving tier still holds the full
            // last epoch).
            let (src, data) = if Some(from) == dead {
                let peer = new_owners.iter().copied().find(|&r| r != to).unwrap_or(to);
                let truth = snapshot_full.get_or_insert_with(|| snapshot.to_full());
                (peer, truth.slice_rows(seg.lo, seg.hi))
            } else {
                let band = self.shards[from].as_ref().expect("live owner without a band");
                let (band_lo, _) = self.plan.node_range(seg.old_part);
                (from, band.slice_rows(seg.lo - band_lo, seg.hi - band_lo))
            };
            moves.push(WireMove { lo: seg.lo, hi: seg.hi, src, dst: to, data });
        }

        // The epoch-fenced transfer. Every move is announced with a
        // fence header carrying the membership epoch; receivers reject a
        // stale fence deterministically before touching the band, which
        // then arrives as a PR 4 chunked stream.
        let rows_moved: usize = moves.iter().map(|m| m.hi - m.lo).sum();
        let span = self
            .owners
            .iter()
            .chain(new_owners.iter())
            .copied()
            .max()
            .unwrap_or(0)
            + 1;
        let moves = Arc::new(moves);
        let mv = Arc::clone(&moves);
        let cluster = Cluster::new(span, self.opts.net)
            .with_cores(self.opts.cores)
            .at_epoch(epoch);
        let (outs, report) = cluster.run(move |ctx| -> Result<Vec<(usize, Matrix)>> {
            for (i, m) in mv.iter().enumerate() {
                if m.src == ctx.rank {
                    let hdr = vec![epoch as u32, (epoch >> 32) as u32, i as u32];
                    ctx.send(m.dst, Tag::of(FENCE_PHASE, i as u32), Payload::U32(hdr));
                    ctx.send_chunked(m.dst, Tag::of(DATA_PHASE, i as u32), m.data.clone());
                }
            }
            let mut got = Vec::new();
            for (i, m) in mv.iter().enumerate() {
                if m.dst == ctx.rank {
                    let hdr = ctx.recv(m.src, Tag::of(FENCE_PHASE, i as u32)).into_u32();
                    anyhow::ensure!(hdr.len() == 3, "malformed fence header");
                    fence(hdr[0] as u64 | ((hdr[1] as u64) << 32), epoch)?;
                    anyhow::ensure!(hdr[2] as usize == i, "fence header move index mismatch");
                    got.push((i, ctx.recv_matrix(m.src, Tag::of(DATA_PHASE, i as u32))));
                }
            }
            Ok(got)
        })?;
        let mut received: Vec<(usize, Matrix)> = Vec::new();
        for out in outs {
            received.extend(out?);
        }

        // Assemble the new bands from keeps + recoveries + arrivals.
        let mut bands: Vec<Matrix> = (0..new_plan.p)
            .map(|pi| Matrix::zeros(new_plan.rows_of(pi), self.dim))
            .collect();
        for &(lo, hi, rank) in &keeps {
            let old_pi = self.plan.node_owner(lo as u32);
            let (old_lo, _) = self.plan.node_range(old_pi);
            let band = self.shards[rank].as_ref().expect("keeper without a band");
            place(&new_plan, &mut bands, lo, &band.slice_rows(lo - old_lo, hi - old_lo));
        }
        for (lo, data) in &recovered {
            place(&new_plan, &mut bands, *lo, data);
        }
        for (i, data) in &received {
            let m = &moves[*i];
            anyhow::ensure!(
                data.rows == m.hi - m.lo && data.cols == self.dim,
                "move {} arrived as {}x{}, want {}x{}",
                i,
                data.rows,
                data.cols,
                m.hi - m.lo,
                self.dim
            );
            place(&new_plan, &mut bands, m.lo, data);
        }

        // Hand off through the double-buffered serving machinery: the old
        // epoch keeps serving in-flight reads, new loads see the new one.
        let table = ShardedTable::from_bands(new_plan.clone(), bands.clone(), 0)?;
        let serving_epoch = self.cell.handoff(table)?;

        // Persist changed bands (store shape is pinned to its band, so a
        // changed band re-creates its store). A departed rank's store is
        // deliberately left behind — it is the grave a kill recovers from
        // and a later rejoin reuses.
        let mut changed: Vec<(usize, usize, usize, usize)> = Vec::new(); // (rank, pi, lo, hi)
        for (pi, &r) in new_owners.iter().enumerate() {
            let (lo, hi) = new_plan.node_range(pi);
            let unchanged = self
                .owners
                .iter()
                .position(|&o| o == r)
                .map(|old_pi| self.plan.node_range(old_pi) == (lo, hi))
                .unwrap_or(false);
            if !unchanged {
                changed.push((r, pi, lo, hi));
            }
        }
        for &(r, pi, lo, hi) in &changed {
            self.persist_shard(r, lo, hi, &bands[pi])?;
        }

        // Install the new world.
        let max_rank = new_owners.iter().copied().max().unwrap_or(0);
        if self.shards.len() <= max_rank {
            self.shards.resize(max_rank + 1, None);
        }
        for &r in &self.owners {
            if !new_owners.contains(&r) {
                self.shards[r] = None;
            }
        }
        for (pi, band) in bands.into_iter().enumerate() {
            self.shards[new_owners[pi]] = Some(band);
        }
        self.plan = new_plan;
        self.owners = new_owners;

        let recover_sim = if rows_recovered > 0 {
            subject_store.as_ref().map(|s| s.sim_secs).unwrap_or(0.0)
        } else {
            0.0
        };
        Ok(MigrationStats {
            event: ev,
            epoch,
            serving_epoch,
            world_after: self.owners.len(),
            rows_moved,
            rows_recovered,
            bytes_on_wire: report.total_bytes(),
            msgs: report.total_msgs(),
            sim_secs: report.makespan() + recover_sim,
            recovered_from_durable: rows_recovered > 0,
        })
    }

    /// Checkpoint `band` as rank `rank`'s per-shard durable store (no-op
    /// without a `durable_root`). The store's WAL pins the band shape, so
    /// a changed band is a fresh `create`; the `band.meta` sidecar (which
    /// `create`'s cleanup leaves alone) records the global row range.
    fn persist_shard(&self, rank: usize, lo: usize, hi: usize, band: &Matrix) -> Result<()> {
        let root = match &self.opts.durable_root {
            Some(r) => r,
            None => return Ok(()),
        };
        let dir = shard_dir(root, rank);
        let store = DurableStore::create(&dir, self.opts.seed, band, DurableOptions::default())?;
        drop(store);
        write_band_meta(&dir, lo, hi)
    }

    /// Replay rank `rank`'s per-shard store, if one is on disk and its
    /// geometry is coherent. `None` means "use the wire".
    fn load_shard(&self, rank: usize) -> Option<LoadedShard> {
        let root = self.opts.durable_root.as_ref()?;
        let dir = shard_dir(root, rank);
        if !DurableStore::exists(&dir) {
            return None;
        }
        let (lo, hi) = read_band_meta(&dir)?;
        let (store, rec) = DurableStore::open(&dir, DurableOptions::default()).ok()?;
        drop(store);
        if rec.table.rows != hi - lo || rec.table.cols != self.dim {
            return None;
        }
        Some(LoadedShard { lo, hi, table: rec.table, sim_secs: rec.sim_secs })
    }
}

/// Write `data` (rows `[lo, lo + data.rows)` of the full table) into the
/// new layout's band that owns it. Segments never straddle a band cut.
fn place(plan: &PartitionPlan, bands: &mut [Matrix], lo: usize, data: &Matrix) {
    let pi = plan.node_owner(lo as u32);
    let (band_lo, band_hi) = plan.node_range(pi);
    assert!(
        lo >= band_lo && lo + data.rows <= band_hi,
        "segment [{}, {}) escapes band {} [{}, {})",
        lo,
        lo + data.rows,
        pi,
        band_lo,
        band_hi
    );
    bands[pi].set_rows(lo - band_lo, data);
}

/// Exact-bit matrix equality (stricter than `PartialEq`: `-0.0 != 0.0`,
/// NaN payloads compare).
fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn band_meta_path(dir: &Path) -> PathBuf {
    dir.join("band.meta")
}

fn write_band_meta(dir: &Path, lo: usize, hi: usize) -> Result<()> {
    std::fs::write(band_meta_path(dir), format!("{} {}\n", lo, hi))?;
    Ok(())
}

fn read_band_meta(dir: &Path) -> Option<(usize, usize)> {
    let s = std::fs::read_to_string(band_meta_path(dir)).ok()?;
    let mut it = s.split_whitespace();
    let lo: usize = it.next()?.parse().ok()?;
    let hi: usize = it.next()?.parse().ok()?;
    (lo <= hi).then_some((lo, hi))
}

/// `HashMap<rank, part>` views come up in callers; kept here so the CLI
/// and tests agree on the mapping.
pub fn part_of_rank(owners: &[usize]) -> HashMap<usize, usize> {
    owners.iter().enumerate().map(|(pi, &r)| (r, pi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn net() -> NetConfig {
        NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 }
    }

    fn opts() -> ElasticOpts {
        ElasticOpts { net: net(), cores: 64.0, seed: 7, min_active: 1, durable_root: None }
    }

    fn reference(n: usize, d: usize) -> Matrix {
        let mut rng = Rng::new(11);
        Matrix::random(n, d, 1.0, &mut rng)
    }

    fn tmp_root(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("deal-member-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn state_machine_fences_epochs() {
        let mut m = Membership::new(3, 2);
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.active(), vec![0, 1, 2]);
        // begin consumes an epoch and marks the subject
        let e = m.begin(MembershipEvent::Leave { rank: 1 }).unwrap();
        assert_eq!(e, 1);
        assert_eq!(m.state(1), RankState::Draining);
        assert_eq!(m.target(), vec![0, 2]);
        assert!(m.in_transition());
        // a second begin is rejected while one is in flight
        assert!(m.begin(MembershipEvent::Join { rank: 5 }).is_err());
        m.commit();
        assert_eq!(m.state(1), RankState::Dead);
        // the floor: 2 active ranks, min_active 2 → no more departures
        assert!(m.begin(MembershipEvent::Leave { rank: 0 }).is_err());
        assert!(m.begin(MembershipEvent::Kill { rank: 2 }).is_err());
        // abort reverts the subject but keeps the epoch consumed
        let e = m.begin(MembershipEvent::Join { rank: 1 }).unwrap();
        assert_eq!(e, 2);
        m.abort();
        assert_eq!(m.state(1), RankState::Dead);
        assert_eq!(m.epoch(), 2, "aborted epochs stay consumed");
        // the fence rejects exactly the mismatches
        assert!(fence(2, 2).is_ok());
        assert_eq!(fence(1, 2), Err(StaleEpoch { got: 1, want: 2 }));
        // a join may target a brand-new rank id
        let e = m.begin(MembershipEvent::Join { rank: 7 }).unwrap();
        assert_eq!(e, 3);
        m.commit();
        assert_eq!(m.active(), vec![0, 2, 7]);
        // an active rank cannot join again
        assert!(m.begin(MembershipEvent::Join { rank: 7 }).is_err());
        // a dead rank cannot leave
        assert!(m.begin(MembershipEvent::Leave { rank: 1 }).is_err());
    }

    #[test]
    fn schedule_parsing() {
        let evs = parse_schedule("join:4, kill:2 ,leave:0").unwrap();
        assert_eq!(
            evs,
            vec![
                MembershipEvent::Join { rank: 4 },
                MembershipEvent::Kill { rank: 2 },
                MembershipEvent::Leave { rank: 0 },
            ]
        );
        assert_eq!(format!("{}", evs[1]), "kill:2");
        assert!(parse_schedule("grow:1").is_err());
        assert!(parse_schedule("join").is_err());
        assert!(parse_schedule("join:x").is_err());
        assert_eq!(parse_schedule("").unwrap(), vec![]);
    }

    #[test]
    fn leave_join_grow_keep_bits() {
        let full = reference(64, 6);
        let mut ec = ElasticCluster::new(&full, 4, opts()).unwrap();
        ec.verify_against(&full).unwrap();
        assert_eq!(ec.serving_epoch(), 0);

        // graceful departure: rank 1 ships its band out
        let s = ec.apply(MembershipEvent::Leave { rank: 1 }).unwrap();
        assert_eq!(s.epoch, 1);
        assert_eq!(s.world_after, 3);
        assert_eq!(ec.owners(), &[0, 2, 3]);
        assert!(s.rows_moved > 0, "a departure must move rows");
        assert!(s.bytes_on_wire > 0);
        ec.verify_against(&full).unwrap();
        assert_eq!(ec.serving_epoch(), 1, "handoff published one epoch");

        // rejoin (no durable root → rows come back over the wire)
        let s = ec.apply(MembershipEvent::Join { rank: 1 }).unwrap();
        assert_eq!(s.epoch, 2);
        assert_eq!(ec.owners(), &[0, 1, 2, 3]);
        assert_eq!(s.rows_recovered, 0);
        ec.verify_against(&full).unwrap();

        // grow beyond the original world
        let s = ec.apply(MembershipEvent::Join { rank: 4 }).unwrap();
        assert_eq!(s.world_after, 5);
        assert_eq!(ec.owners(), &[0, 1, 2, 3, 4]);
        ec.verify_against(&full).unwrap();
        assert_eq!(ec.history().len(), 3);
    }

    #[test]
    fn kill_recovers_from_durable_and_rejoin_reuses_grave() {
        let root = tmp_root("kill");
        let full = reference(60, 5);
        let mut o = opts();
        o.durable_root = Some(root.clone());
        let mut ec = ElasticCluster::new(&full, 3, o).unwrap();

        // the victim's whole band comes back from its store, not the wire
        let victim = 2usize;
        let victim_rows = ec.plan().rows_of(2);
        let s = ec.apply(MembershipEvent::Kill { rank: victim }).unwrap();
        assert!(s.recovered_from_durable);
        assert_eq!(s.rows_recovered, victim_rows, "the grave supplies the whole lost band");
        assert!(s.sim_secs > 0.0);
        ec.verify_against(&full).unwrap();

        // rejoin-from-durable: the rank's grave still covers part of its
        // new band, so some rows never touch the wire on the way back
        let s = ec.apply(MembershipEvent::Join { rank: victim }).unwrap();
        assert!(s.recovered_from_durable, "rejoin must reuse the grave");
        assert!(s.rows_recovered > 0);
        ec.verify_against(&full).unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn kill_without_durable_rebuilds_from_peers() {
        let full = reference(48, 4);
        let mut ec = ElasticCluster::new(&full, 3, opts()).unwrap();
        let victim_rows = ec.plan().rows_of(1);
        let s = ec.apply(MembershipEvent::Kill { rank: 1 }).unwrap();
        assert!(!s.recovered_from_durable);
        assert_eq!(s.rows_recovered, 0);
        assert!(s.rows_moved >= victim_rows, "the lost band must ride the wire");
        ec.verify_against(&full).unwrap();
    }

    #[test]
    fn incremental_moves_strictly_less_than_full_reshard() {
        let full = reference(96, 8);
        let mut inc = ElasticCluster::new(&full, 4, opts()).unwrap();
        let mut naive = ElasticCluster::new(&full, 4, opts()).unwrap();
        let ev = MembershipEvent::Leave { rank: 3 };
        let si = inc.apply_mode(ev, MigrationMode::Incremental).unwrap();
        let sf = naive.apply_mode(ev, MigrationMode::FullReshard).unwrap();
        assert!(si.rows_moved < sf.rows_moved, "inc={} full={}", si.rows_moved, sf.rows_moved);
        assert!(
            si.bytes_on_wire < sf.bytes_on_wire,
            "inc={} full={}",
            si.bytes_on_wire,
            sf.bytes_on_wire
        );
        inc.verify_against(&full).unwrap();
        naive.verify_against(&full).unwrap();
        assert_eq!(sf.rows_moved, full.rows, "naive baseline re-ships every row");
    }

    #[test]
    fn floor_and_world_invariants_hold() {
        let full = reference(20, 3);
        let mut o = opts();
        o.min_active = 2;
        let mut ec = ElasticCluster::new(&full, 2, o).unwrap();
        // shrinking below the floor is refused before any epoch is spent
        assert!(ec.apply(MembershipEvent::Leave { rank: 0 }).is_err());
        assert_eq!(ec.epoch(), 0, "a refused transition consumes no epoch");
        ec.verify_against(&full).unwrap();
        // the part → rank map is coherent
        let map = part_of_rank(ec.owners());
        assert_eq!(map.len(), 2);
        assert_eq!(map[&0], 0);
    }
}
