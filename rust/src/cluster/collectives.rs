//! Collective communication built on the point-to-point substrate.
//!
//! Deal's GEMM uses a **ring all-to-all** (paper §3.4: "we implement a
//! ring-based all-to-all communication to pipeline the computation");
//! CAGNET's baseline GEMM uses an all-gather of partial results. Both are
//! expressed here over a machine *subgroup* (the M machines sharing one
//! graph partition's rows).

use super::net::{Payload, Tag};
use super::Ctx;
use crate::tensor::Matrix;

/// Ring all-to-all over a subgroup: every member contributes one block for
/// every other member; block `j` from member `i` reaches member `j` after
/// at most `group.len()-1` ring hops... but since our links are
/// fully-connected we implement the standard M−1 *stages* where at stage
/// `s`, member `i` sends directly to `(i+s) mod M` — this preserves the
/// ring's pipelining property (each stage's send can overlap the previous
/// stage's compute) while matching the paper's communication volume
/// `(M-1)` blocks per member.
///
/// `blocks[j]` is this member's block destined for subgroup position `j`
/// (`blocks[my_pos]` stays local). Returns the received blocks indexed by
/// source subgroup position, with `out[my_pos] = blocks[my_pos]`.
///
/// Transfers are **chunk-granular** (paper §4): every block ships as
/// row-band chunks via `Ctx::send_chunked`, each stamped with its own
/// link-completion time, and is reassembled with `Ctx::recv_matrix` — so
/// the wire schedule matches the pipelined primitives even when the
/// caller wants whole blocks. `deal_gemm` goes further and folds its
/// per-band compute into the ring inline (`Ctx::recv_stream`), which is
/// the Fig. 7b compute/communication overlap.
pub fn ring_all_to_all(
    ctx: &mut Ctx,
    group: &[usize],
    my_pos: usize,
    mut blocks: Vec<Matrix>,
    phase: u32,
) -> Vec<Matrix> {
    let m = group.len();
    assert_eq!(blocks.len(), m);
    assert_eq!(group[my_pos], ctx.rank);
    let mut out: Vec<Option<Matrix>> = (0..m).map(|_| None).collect();
    // Issue all sends up front (non-blocking): stage s sends to (pos+s)%m.
    for s in 1..m {
        let dst_pos = (my_pos + s) % m;
        let block = std::mem::replace(&mut blocks[dst_pos], Matrix::zeros(0, 0));
        ctx.send_chunked(group[dst_pos], Tag::of(phase, s as u32), block);
    }
    out[my_pos] = Some(std::mem::replace(&mut blocks[my_pos], Matrix::zeros(0, 0)));
    // Receive stage by stage: at stage s we hear from (pos-s) mod m.
    for s in 1..m {
        let src_pos = (my_pos + m - s) % m;
        out[src_pos] = Some(ctx.recv_matrix(group[src_pos], Tag::of(phase, s as u32)));
    }
    out.into_iter().map(|b| b.unwrap()).collect()
}

/// All-gather over a subgroup: every member broadcasts its block to the
/// others; returns blocks indexed by subgroup position. This is the
/// communication pattern of CAGNET's GEMM aggregation step.
pub fn all_gather(
    ctx: &mut Ctx,
    group: &[usize],
    my_pos: usize,
    block: Matrix,
    phase: u32,
) -> Vec<Matrix> {
    let m = group.len();
    assert_eq!(group[my_pos], ctx.rank);
    for (pos, &rank) in group.iter().enumerate() {
        if pos != my_pos {
            ctx.send(rank, Tag::of(phase, my_pos as u32), Payload::Matrix(block.clone()));
        }
    }
    let mut out: Vec<Option<Matrix>> = (0..m).map(|_| None).collect();
    out[my_pos] = Some(block);
    for (pos, &rank) in group.iter().enumerate() {
        if pos != my_pos {
            out[pos] = Some(ctx.recv(rank, Tag::of(phase, pos as u32)).into_matrix());
        }
    }
    out.into_iter().map(|b| b.unwrap()).collect()
}

/// All-reduce (sum) over a subgroup via all-gather + local sum. CAGNET's
/// GEMM effectively pays this on full-size intermediates — which is exactly
/// the overhead Table 1 charges it for — so the simple implementation is
/// faithful.
pub fn all_reduce_sum(
    ctx: &mut Ctx,
    group: &[usize],
    my_pos: usize,
    block: Matrix,
    phase: u32,
) -> Matrix {
    let blocks = all_gather(ctx, group, my_pos, block, phase);
    let mut acc = blocks[0].clone();
    for b in &blocks[1..] {
        assert_eq!((acc.rows, acc.cols), (b.rows, b.cols));
        for (a, &v) in acc.data.iter_mut().zip(&b.data) {
            *a += v;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};

    #[test]
    fn ring_all_to_all_delivers_all_blocks() {
        let world = 4;
        let cluster = Cluster::new(world, NetConfig::default());
        let (vals, _) = cluster
            .run(move |ctx| {
                let group: Vec<usize> = (0..ctx.world).collect();
                // member i sends to j the 1x1 matrix [i*10 + j]
                let blocks: Vec<Matrix> = (0..ctx.world)
                    .map(|j| Matrix::from_vec(1, 1, vec![(ctx.rank * 10 + j) as f32]))
                    .collect();
                let got = ring_all_to_all(ctx, &group, ctx.rank, blocks, 1);
                got.iter().map(|m| m.data[0] as usize).collect::<Vec<_>>()
            })
            .unwrap();
        for (rank, got) in vals.iter().enumerate() {
            let expect: Vec<usize> = (0..world).map(|src| src * 10 + rank).collect();
            assert_eq!(got, &expect, "rank {}", rank);
        }
    }

    #[test]
    fn ring_all_to_all_chunked_matches_monolithic() {
        // 20-row blocks at 6-row chunks (4 chunks each): results must be
        // bit-identical to the monolithic ring, with chunked wire traffic.
        fn blocks_for(rank: usize, world: usize) -> Vec<Matrix> {
            (0..world)
                .map(|j| {
                    let mut m = Matrix::zeros(20, 4);
                    for (i, v) in m.data.iter_mut().enumerate() {
                        *v = (rank * 1000 + j * 100 + i) as f32;
                    }
                    m
                })
                .collect()
        }
        let run = |chunk: usize| {
            crate::cluster::net::with_chunk_rows(chunk, || {
                Cluster::new(3, NetConfig::default())
                    .run(|ctx| {
                        let group: Vec<usize> = (0..ctx.world).collect();
                        let blocks = blocks_for(ctx.rank, ctx.world);
                        ring_all_to_all(ctx, &group, ctx.rank, blocks, 5)
                    })
                    .unwrap()
            })
        };
        let (mono, mono_rep) = run(0);
        let (chunked, rep) = run(6);
        assert_eq!(mono, chunked);
        assert_eq!(mono_rep.total_chunks(), 0);
        // each rank sends 2 remote blocks of 4 chunks each
        assert_eq!(rep.total_chunks(), 3 * 2 * 4);
    }

    #[test]
    fn all_gather_collects_in_position_order() {
        let cluster = Cluster::new(3, NetConfig::default());
        let (vals, _) = cluster
            .run(|ctx| {
                let group: Vec<usize> = (0..ctx.world).collect();
                let mine = Matrix::from_vec(1, 1, vec![ctx.rank as f32]);
                let got = all_gather(ctx, &group, ctx.rank, mine, 2);
                got.iter().map(|m| m.data[0] as usize).collect::<Vec<_>>()
            })
            .unwrap();
        for got in vals {
            assert_eq!(got, vec![0, 1, 2]);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let cluster = Cluster::new(4, NetConfig::default());
        let (vals, _) = cluster
            .run(|ctx| {
                let group: Vec<usize> = (0..ctx.world).collect();
                let mine = Matrix::from_vec(1, 2, vec![ctx.rank as f32, 1.0]);
                all_reduce_sum(ctx, &group, ctx.rank, mine, 3).data
            })
            .unwrap();
        for v in vals {
            assert_eq!(v, vec![6.0, 4.0]); // 0+1+2+3, 1*4
        }
    }

    #[test]
    fn subgroup_collectives_do_not_cross() {
        // two disjoint subgroups of a 4-machine world
        let cluster = Cluster::new(4, NetConfig::default());
        let (vals, _) = cluster
            .run(|ctx| {
                let group: Vec<usize> = if ctx.rank < 2 { vec![0, 1] } else { vec![2, 3] };
                let my_pos = ctx.rank % 2;
                let mine = Matrix::from_vec(1, 1, vec![ctx.rank as f32]);
                let got = all_gather(ctx, &group, my_pos, mine, 4);
                got.iter().map(|m| m.data[0] as usize).collect::<Vec<_>>()
            })
            .unwrap();
        assert_eq!(vals[0], vec![0, 1]);
        assert_eq!(vals[1], vec![0, 1]);
        assert_eq!(vals[2], vec![2, 3]);
        assert_eq!(vals[3], vec![2, 3]);
    }
}
