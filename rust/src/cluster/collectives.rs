//! Collective communication built on the point-to-point substrate.
//!
//! Deal's GEMM uses a **ring all-to-all** (paper §3.4: "we implement a
//! ring-based all-to-all communication to pipeline the computation");
//! CAGNET's baseline GEMM uses an all-gather of partial results. Both are
//! expressed here over a machine *subgroup* (the M machines sharing one
//! graph partition's rows).

use std::cell::Cell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::net::{Payload, Tag};
use super::Ctx;
use crate::tensor::Matrix;

/// Direction the ring all-to-all walks the subgroup. Both directions move
/// the same blocks between the same pairs — only the stage at which each
/// pair communicates changes — so results are bit-identical (the output is
/// indexed by *source position*, not arrival order). The knob exists as an
/// execution variant the autotuner can schedule and the oracle tests can
/// prove direction-invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingDir {
    /// Stage `s` sends to `(pos + s) mod M` (the default).
    Forward,
    /// Stage `s` sends to `(pos - s) mod M`.
    Reverse,
}

impl RingDir {
    pub fn name(self) -> &'static str {
        match self {
            RingDir::Forward => "forward",
            RingDir::Reverse => "reverse",
        }
    }
}

/// Sentinel for "no override" in the u8-encoded knob chain
/// (0 = Forward, 1 = Reverse, 2 = unset).
const DIR_UNSET: u8 = 2;

static GLOBAL_RING_DIR: AtomicU8 = AtomicU8::new(DIR_UNSET);

thread_local! {
    static LOCAL_RING_DIR: Cell<u8> = const { Cell::new(DIR_UNSET) };
}

fn dir_to_u8(d: RingDir) -> u8 {
    match d {
        RingDir::Forward => 0,
        RingDir::Reverse => 1,
    }
}

fn dir_from_u8(v: u8) -> Option<RingDir> {
    match v {
        0 => Some(RingDir::Forward),
        1 => Some(RingDir::Reverse),
        _ => None,
    }
}

/// Set the process-global ring direction.
pub fn set_ring_dir(dir: RingDir) {
    GLOBAL_RING_DIR.store(dir_to_u8(dir), Ordering::Relaxed);
}

/// Reset the process-global ring direction to auto (`DEAL_RING_DIR` env,
/// else Forward).
pub fn clear_ring_dir() {
    GLOBAL_RING_DIR.store(DIR_UNSET, Ordering::Relaxed);
}

/// Run `f` with the ring direction pinned on this thread (restored on
/// exit). `Cluster::run` and `Ctx::with_server` capture the caller's
/// effective direction into spawned rank/server threads.
pub fn with_ring_dir<T>(dir: RingDir, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_RING_DIR.with(|c| c.replace(dir_to_u8(dir)));
    let out = f();
    LOCAL_RING_DIR.with(|c| c.set(prev));
    out
}

fn env_ring_dir_default() -> RingDir {
    static ENV: OnceLock<RingDir> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("DEAL_RING_DIR").as_deref() {
        Ok("reverse") | Ok("1") => RingDir::Reverse,
        _ => RingDir::Forward,
    })
}

/// Effective ring direction for this thread: [`with_ring_dir`] scope →
/// [`set_ring_dir`] global → `DEAL_RING_DIR` env (`reverse`/`1`) → Forward.
pub fn ring_dir() -> RingDir {
    if let Some(d) = dir_from_u8(LOCAL_RING_DIR.with(|c| c.get())) {
        return d;
    }
    if let Some(d) = dir_from_u8(GLOBAL_RING_DIR.load(Ordering::Relaxed)) {
        return d;
    }
    env_ring_dir_default()
}

/// Ring all-to-all over a subgroup: every member contributes one block for
/// every other member; block `j` from member `i` reaches member `j` after
/// at most `group.len()-1` ring hops... but since our links are
/// fully-connected we implement the standard M−1 *stages* where at stage
/// `s`, member `i` sends directly to `(i+s) mod M` — this preserves the
/// ring's pipelining property (each stage's send can overlap the previous
/// stage's compute) while matching the paper's communication volume
/// `(M-1)` blocks per member.
///
/// `blocks[j]` is this member's block destined for subgroup position `j`
/// (`blocks[my_pos]` stays local). Returns the received blocks indexed by
/// source subgroup position, with `out[my_pos] = blocks[my_pos]`.
///
/// Transfers are **chunk-granular** (paper §4): every block ships as
/// row-band chunks via `Ctx::send_chunked`, each stamped with its own
/// link-completion time, and is reassembled with `Ctx::recv_matrix` — so
/// the wire schedule matches the pipelined primitives even when the
/// caller wants whole blocks. `deal_gemm` goes further and folds its
/// per-band compute into the ring inline (`Ctx::recv_stream`), which is
/// the Fig. 7b compute/communication overlap.
pub fn ring_all_to_all(
    ctx: &mut Ctx,
    group: &[usize],
    my_pos: usize,
    mut blocks: Vec<Matrix>,
    phase: u32,
) -> Vec<Matrix> {
    let m = group.len();
    assert_eq!(blocks.len(), m);
    assert_eq!(group[my_pos], ctx.rank);
    let dir = ring_dir();
    let mut out: Vec<Option<Matrix>> = (0..m).map(|_| None).collect();
    // Issue all sends up front (non-blocking): stage s sends to (pos+s)%m
    // walking forward, (pos-s)%m walking reverse. Every member uses the
    // same effective direction (installed by the cluster launcher), so the
    // stage pairings stay symmetric: whoever I send to at stage s is
    // expecting my block at stage s.
    for s in 1..m {
        let dst_pos = match dir {
            RingDir::Forward => (my_pos + s) % m,
            RingDir::Reverse => (my_pos + m - s) % m,
        };
        let block = std::mem::replace(&mut blocks[dst_pos], Matrix::zeros(0, 0));
        ctx.send_chunked(group[dst_pos], Tag::of(phase, s as u32), block);
    }
    out[my_pos] = Some(std::mem::replace(&mut blocks[my_pos], Matrix::zeros(0, 0)));
    // Receive stage by stage from the mirror of the send mapping. Output
    // is indexed by source position, so direction never changes values.
    for s in 1..m {
        let src_pos = match dir {
            RingDir::Forward => (my_pos + m - s) % m,
            RingDir::Reverse => (my_pos + s) % m,
        };
        out[src_pos] = Some(ctx.recv_matrix(group[src_pos], Tag::of(phase, s as u32)));
    }
    out.into_iter().map(|b| b.unwrap()).collect()
}

/// All-gather over a subgroup: every member broadcasts its block to the
/// others; returns blocks indexed by subgroup position. This is the
/// communication pattern of CAGNET's GEMM aggregation step.
pub fn all_gather(
    ctx: &mut Ctx,
    group: &[usize],
    my_pos: usize,
    block: Matrix,
    phase: u32,
) -> Vec<Matrix> {
    let m = group.len();
    assert_eq!(group[my_pos], ctx.rank);
    for (pos, &rank) in group.iter().enumerate() {
        if pos != my_pos {
            ctx.send(rank, Tag::of(phase, my_pos as u32), Payload::Matrix(block.clone()));
        }
    }
    let mut out: Vec<Option<Matrix>> = (0..m).map(|_| None).collect();
    out[my_pos] = Some(block);
    for (pos, &rank) in group.iter().enumerate() {
        if pos != my_pos {
            out[pos] = Some(ctx.recv(rank, Tag::of(phase, pos as u32)).into_matrix());
        }
    }
    out.into_iter().map(|b| b.unwrap()).collect()
}

/// All-reduce (sum) over a subgroup via all-gather + local sum. CAGNET's
/// GEMM effectively pays this on full-size intermediates — which is exactly
/// the overhead Table 1 charges it for — so the simple implementation is
/// faithful.
pub fn all_reduce_sum(
    ctx: &mut Ctx,
    group: &[usize],
    my_pos: usize,
    block: Matrix,
    phase: u32,
) -> Matrix {
    let blocks = all_gather(ctx, group, my_pos, block, phase);
    let mut acc = blocks[0].clone();
    for b in &blocks[1..] {
        assert_eq!((acc.rows, acc.cols), (b.rows, b.cols));
        for (a, &v) in acc.data.iter_mut().zip(&b.data) {
            *a += v;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, NetConfig};

    #[test]
    fn ring_all_to_all_delivers_all_blocks() {
        let world = 4;
        let cluster = Cluster::new(world, NetConfig::default());
        let (vals, _) = cluster
            .run(move |ctx| {
                let group: Vec<usize> = (0..ctx.world).collect();
                // member i sends to j the 1x1 matrix [i*10 + j]
                let blocks: Vec<Matrix> = (0..ctx.world)
                    .map(|j| Matrix::from_vec(1, 1, vec![(ctx.rank * 10 + j) as f32]))
                    .collect();
                let got = ring_all_to_all(ctx, &group, ctx.rank, blocks, 1);
                got.iter().map(|m| m.data[0] as usize).collect::<Vec<_>>()
            })
            .unwrap();
        for (rank, got) in vals.iter().enumerate() {
            let expect: Vec<usize> = (0..world).map(|src| src * 10 + rank).collect();
            assert_eq!(got, &expect, "rank {}", rank);
        }
    }

    #[test]
    fn ring_all_to_all_chunked_matches_monolithic() {
        // 20-row blocks at 6-row chunks (4 chunks each): results must be
        // bit-identical to the monolithic ring, with chunked wire traffic.
        fn blocks_for(rank: usize, world: usize) -> Vec<Matrix> {
            (0..world)
                .map(|j| {
                    let mut m = Matrix::zeros(20, 4);
                    for (i, v) in m.data.iter_mut().enumerate() {
                        *v = (rank * 1000 + j * 100 + i) as f32;
                    }
                    m
                })
                .collect()
        }
        let run = |chunk: usize| {
            crate::cluster::net::with_chunk_rows(chunk, || {
                Cluster::new(3, NetConfig::default())
                    .run(|ctx| {
                        let group: Vec<usize> = (0..ctx.world).collect();
                        let blocks = blocks_for(ctx.rank, ctx.world);
                        ring_all_to_all(ctx, &group, ctx.rank, blocks, 5)
                    })
                    .unwrap()
            })
        };
        let (mono, mono_rep) = run(0);
        let (chunked, rep) = run(6);
        assert_eq!(mono, chunked);
        assert_eq!(mono_rep.total_chunks(), 0);
        // each rank sends 2 remote blocks of 4 chunks each
        assert_eq!(rep.total_chunks(), 3 * 2 * 4);
    }

    #[test]
    fn ring_all_to_all_direction_invariant() {
        // Reverse walks the ring the other way (different wire schedule)
        // but must deliver bit-identical blocks: output is indexed by
        // source position, not arrival order.
        let run = |dir: RingDir| {
            with_ring_dir(dir, || {
                Cluster::new(4, NetConfig::default())
                    .run(|ctx| {
                        let group: Vec<usize> = (0..ctx.world).collect();
                        let blocks: Vec<Matrix> = (0..ctx.world)
                            .map(|j| {
                                let mut m = Matrix::zeros(8, 3);
                                for (i, v) in m.data.iter_mut().enumerate() {
                                    *v = (ctx.rank * 1000 + j * 100 + i) as f32;
                                }
                                m
                            })
                            .collect();
                        ring_all_to_all(ctx, &group, ctx.rank, blocks, 9)
                    })
                    .unwrap()
            })
        };
        let (fwd, _) = run(RingDir::Forward);
        let (rev, _) = run(RingDir::Reverse);
        assert_eq!(fwd, rev);
    }

    #[test]
    fn ring_dir_knob_chain_resolves() {
        assert_eq!(ring_dir(), RingDir::Forward, "default forward");
        with_ring_dir(RingDir::Reverse, || {
            assert_eq!(ring_dir(), RingDir::Reverse);
            with_ring_dir(RingDir::Forward, || assert_eq!(ring_dir(), RingDir::Forward));
            assert_eq!(ring_dir(), RingDir::Reverse);
        });
        assert_eq!(ring_dir(), RingDir::Forward);
    }

    #[test]
    fn all_gather_collects_in_position_order() {
        let cluster = Cluster::new(3, NetConfig::default());
        let (vals, _) = cluster
            .run(|ctx| {
                let group: Vec<usize> = (0..ctx.world).collect();
                let mine = Matrix::from_vec(1, 1, vec![ctx.rank as f32]);
                let got = all_gather(ctx, &group, ctx.rank, mine, 2);
                got.iter().map(|m| m.data[0] as usize).collect::<Vec<_>>()
            })
            .unwrap();
        for got in vals {
            assert_eq!(got, vec![0, 1, 2]);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let cluster = Cluster::new(4, NetConfig::default());
        let (vals, _) = cluster
            .run(|ctx| {
                let group: Vec<usize> = (0..ctx.world).collect();
                let mine = Matrix::from_vec(1, 2, vec![ctx.rank as f32, 1.0]);
                all_reduce_sum(ctx, &group, ctx.rank, mine, 3).data
            })
            .unwrap();
        for v in vals {
            assert_eq!(v, vec![6.0, 4.0]); // 0+1+2+3, 1*4
        }
    }

    #[test]
    fn subgroup_collectives_do_not_cross() {
        // two disjoint subgroups of a 4-machine world
        let cluster = Cluster::new(4, NetConfig::default());
        let (vals, _) = cluster
            .run(|ctx| {
                let group: Vec<usize> = if ctx.rank < 2 { vec![0, 1] } else { vec![2, 3] };
                let my_pos = ctx.rank % 2;
                let mine = Matrix::from_vec(1, 1, vec![ctx.rank as f32]);
                let got = all_gather(ctx, &group, my_pos, mine, 4);
                got.iter().map(|m| m.data[0] as usize).collect::<Vec<_>>()
            })
            .unwrap();
        assert_eq!(vals[0], vec![0, 1]);
        assert_eq!(vals[1], vec![0, 1]);
        assert_eq!(vals[2], vec![2, 3]);
        assert_eq!(vals[3], vec![2, 3]);
    }
}
