//! The simulated distributed substrate.
//!
//! The paper runs on 16 AWS R5.16xlarge instances over 25 Gbps Ethernet;
//! this environment is a single box, so the cluster is *simulated but not
//! faked*: every machine is an OS thread, every message really moves its
//! bytes through a channel, and a **Lamport-clock network model** assigns
//! each machine a simulated clock:
//!
//! - `Ctx::compute(f)` runs `f` and advances the local clock by the
//!   *thread-CPU time* `f` consumed divided by `cores_per_machine`
//!   (R5.16xlarge machines have 64 vCPUs; intra-machine parallel kernels
//!   are outside our scope, so the measured single-thread time is scaled
//!   by a configurable factor — default 64 = the testbed vCPU count — to land the
//!   simulation in the paper's comm/compute regime).
//! - `Ctx::send` is non-blocking (NIC-offload semantics, matching the
//!   paper's comm/compute overlap) and stamps the message with its network
//!   completion time: `max(sender clock, link busy) + latency + bytes/bw`,
//!   serialized per directed link.
//! - `Ctx::recv` blocks for the data and advances the local clock to
//!   `max(local clock, message ready time)` — so a machine that computed
//!   while the transfer was in flight pays nothing extra (pipelining), and
//!   a machine that waited sees the wait. This is exactly the mechanism
//!   that reproduces the Fig. 12 pipeline schedules.
//! - `Ctx::send_chunked` / `Ctx::recv_stream` split a large matrix into
//!   row-band chunks, each with its own link-completion stamp, so the
//!   receiver's per-band compute overlaps the tail of the transfer at
//!   *chunk* granularity (paper §4 "partitioned, pipelined communication";
//!   DESIGN.md §Pipelined-communication). Granularity: `net::chunk_rows`.
//!
//! The simulated makespan (`ClusterReport::makespan`) is the maximum final
//! clock; per-machine byte counters feed the Table 1–3 validations.

/// Collectives (ring all-to-all, all-gather, all-reduce) over the
/// point-to-point substrate.
pub mod collectives;
/// Per-machine peak-memory accounting.
pub mod memory;
/// Per-machine and cluster-level counters and reports.
pub mod metrics;
/// The LogP-ish link model, payloads, and the chunk-granularity knob.
pub mod net;

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

use crate::tensor::Matrix;
use crate::Result;
/// Elastic membership: epoch-fenced join/leave/kill state machine,
/// incremental shard migration, and kill-and-rejoin recovery.
pub mod membership;

pub use memory::MemTracker;
pub use metrics::{ClusterReport, MachineMetrics, RankFailed};
pub use net::{
    chunk_rows, set_chunk_rows, with_chunk_rows, LinkTable, Message, NetConfig, Payload, PeerDied,
    Tag,
};

/// Per-machine execution context handed to the closure running on each
/// simulated machine.
pub struct Ctx {
    /// This machine's rank in `0..world`.
    pub rank: usize,
    /// Number of simulated machines in the cluster.
    pub world: usize,
    /// Simulated local clock, seconds.
    clock: f64,
    senders: Vec<Sender<Message>>,
    /// Service-plane senders (requests addressed to peers' server threads).
    service_senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    /// Service-plane inbox; taken by `spawn_server` while a server runs.
    service_inbox: Option<Receiver<Message>>,
    /// Service messages received ahead of their phase (a fast peer can
    /// start the next primitive while our server still drains this one).
    service_stash: std::collections::VecDeque<Message>,
    /// Messages received but not yet matched by `(src, tag)`.
    stash: HashMap<(usize, u64), std::collections::VecDeque<Message>>,
    links: Arc<LinkTable>,
    barrier: Arc<Barrier>,
    barrier_clock: Arc<Mutex<f64>>,
    /// Compute-time divisor (cores per machine).
    cores: f64,
    /// Peak-memory tracker for this machine.
    pub mem: MemTracker,
    /// Communication/computation counters for this machine.
    pub metrics: MachineMetrics,
}

impl Ctx {
    /// Run `f`, advancing the simulated clock by the **total** CPU time it
    /// consumed — the calling thread plus every `runtime::par` pool worker
    /// it fanned out to — scaled by the machine's core count, plus a
    /// fork/join overhead term per spawned worker
    /// (`costs::intra_rank_compute_secs`). Charging total CPU rather than
    /// caller wall time keeps simulated makespans honest now that the hot
    /// kernels are intra-rank parallel. Returns `f`'s value.
    pub fn compute<T>(&mut self, f: impl FnOnce() -> T) -> T {
        crate::runtime::par::take_child_accounting(); // clear stale ledger
        let t0 = thread_cpu_time();
        let v = f();
        let main = (thread_cpu_time() - t0).max(0.0);
        let (child, forks) = crate::runtime::par::take_child_accounting();
        let dt =
            crate::primitives::costs::intra_rank_compute_secs(main + child, forks, self.cores);
        self.clock += dt;
        self.metrics.sim_compute_secs += dt;
        v
    }

    /// Advance the clock by an explicit duration (used when a cost is
    /// modeled rather than measured, e.g. file-system scan time).
    pub fn advance(&mut self, secs: f64) {
        self.clock += secs;
        self.metrics.sim_compute_secs += secs;
    }

    /// Current simulated time on this machine.
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Non-blocking send of `payload` to machine `dst` under `tag`.
    /// A transport fault boundary (`net::fault`): an armed kill fires
    /// here; an armed delay adds simulated latency to the transfer.
    pub fn send(&mut self, dst: usize, tag: Tag, payload: Payload) {
        net::fault::step(self.rank, net::fault::FaultPoint::Send);
        let bytes = payload.nbytes();
        let ready_at =
            self.links.schedule(self.rank, dst, self.clock, bytes) + net::fault::send_delay(self.rank);
        self.metrics.bytes_sent += bytes;
        self.metrics.msgs_sent += 1;
        let msg = Message { src: self.rank, tag: tag.0, ready_at, payload };
        // Unbounded channel: sends never block, so symmetric exchanges
        // cannot deadlock.
        self.senders[dst].send(msg).expect("receiver hung up");
    }

    /// Blocking receive of the next message from `src` with `tag`.
    /// Advances the simulated clock to the transfer completion time.
    /// A transport fault boundary (`net::fault`), checked before
    /// blocking.
    pub fn recv(&mut self, src: usize, tag: Tag) -> Payload {
        net::fault::step(self.rank, net::fault::FaultPoint::Recv);
        let msg = self.wait_for(src, tag.0);
        let wait = (msg.ready_at - self.clock).max(0.0);
        self.metrics.sim_comm_wait_secs += wait;
        self.clock = self.clock.max(msg.ready_at);
        self.metrics.bytes_recv += msg.payload.nbytes();
        self.metrics.msgs_recv += 1;
        msg.payload
    }

    /// Like `recv`, but does not advance the clock past the data-ready time
    /// if it is already later (identical semantics; exposed for clarity).
    fn wait_for(&mut self, src: usize, tag: u64) -> Message {
        if let Some(q) = self.stash.get_mut(&(src, tag)) {
            if let Some(m) = q.pop_front() {
                return m;
            }
        }
        loop {
            let m = self.inbox.recv().expect("cluster channel closed");
            if m.tag == net::POISON_TAG {
                // A peer died mid-protocol; the data this rank is blocked
                // on will never arrive. Abort (collateral, not root cause
                // — see `Cluster::run`) instead of stalling the cluster.
                std::panic::resume_unwind(Box::new(PeerDied { src: m.src }));
            }
            if m.src == src && m.tag == tag {
                return m;
            }
            self.stash
                .entry((m.src, m.tag))
                .or_default()
                .push_back(m);
        }
    }

    /// Send a control message that consumes no link time: stamped ready
    /// at the sender's current clock. Models in-band frame metadata (a
    /// real wire carries the chunk count inside the first frame's
    /// header); its bytes still land in the counters.
    fn send_control(&mut self, dst: usize, tag: Tag, payload: Payload) {
        let bytes = payload.nbytes();
        self.metrics.bytes_sent += bytes;
        self.metrics.msgs_sent += 1;
        let msg = Message { src: self.rank, tag: tag.0, ready_at: self.clock, payload };
        self.senders[dst].send(msg).expect("receiver hung up");
    }

    /// Send `m` to `dst` as a pipelined sequence of row-band chunks (the
    /// paper's §4 "partitioned, pipelined communication"): each chunk is
    /// scheduled on the link separately and carries its own completion
    /// stamp, so a receiver using [`Ctx::recv_stream`] /
    /// [`Ctx::open_stream`] computes on early bands while later bands are
    /// still in flight. Granularity comes from [`net::chunk_rows`]; `0`,
    /// or a matrix at most one chunk tall, falls back to one monolithic
    /// message (exactly the pre-pipelining behavior). Chunks ride the
    /// same `(src, tag)` FIFO the link already serializes; a zero-link-
    /// time header announces the chunk count (in-band metadata), so the
    /// receive side is self-describing, never needs to agree on the knob,
    /// and the wire time is exactly `k·lat + bytes/bw`
    /// (`NetConfig::chunked_transfer_secs`).
    pub fn send_chunked(&mut self, dst: usize, tag: Tag, m: Matrix) {
        match net::chunk_plan(m.rows, m.cols) {
            None => self.send(dst, tag, Payload::Matrix(m)),
            Some((header, bounds)) => {
                self.metrics.chunks_sent += (bounds.len() - 1) as u64;
                self.send_control(dst, tag, Payload::U32(header));
                for w in bounds.windows(2) {
                    self.send(dst, tag, Payload::Matrix(m.slice_rows(w[0], w[1])));
                }
            }
        }
    }

    /// Begin receiving a (possibly chunked) matrix transfer from `src`
    /// under `tag` — the receive side of [`Ctx::send_chunked`]. Pulls the
    /// header (or the sole monolithic payload) immediately; chunks are
    /// then drawn one at a time with [`MatrixStream::next`], advancing
    /// this machine's clock to each chunk's own link-completion stamp.
    /// The stream holds no borrow of the context, so callers can
    /// interleave several concurrent streams (the distributed SDDMM
    /// completes one row band across `M` column-slice streams before
    /// computing on it).
    pub fn open_stream(&mut self, src: usize, tag: Tag) -> MatrixStream {
        match self.recv(src, tag) {
            Payload::Matrix(m) => MatrixStream {
                src,
                tag,
                rows: m.rows,
                cols: m.cols,
                next_row: 0,
                chunks_left: 0,
                whole: Some(m),
            },
            Payload::U32(hdr) => {
                assert_eq!(hdr.len(), 3, "malformed chunk header");
                let (n, rows, cols) = (hdr[0] as usize, hdr[1] as usize, hdr[2] as usize);
                self.metrics.chunks_recv += n as u64;
                MatrixStream { src, tag, rows, cols, next_row: 0, chunks_left: n, whole: None }
            }
            other => panic!("expected Matrix or chunk header, got {:?}", other.kind()),
        }
    }

    /// Receive a chunked transfer, invoking `f` on every row band as it
    /// completes (with the band's row range in the full matrix). Feeding
    /// each band straight into a kernel makes the step cost
    /// `max(comm, compute) + fill` instead of `comm + compute`
    /// (`primitives::costs::pipelined_step_secs`). Returns the transfer's
    /// `(rows, cols)`.
    pub fn recv_stream(
        &mut self,
        src: usize,
        tag: Tag,
        mut f: impl FnMut(&mut Ctx, std::ops::Range<usize>, Matrix),
    ) -> (usize, usize) {
        let mut s = self.open_stream(src, tag);
        while let Some((band, chunk)) = s.next(self) {
            f(self, band, chunk);
        }
        (s.rows, s.cols)
    }

    /// Receive a chunked transfer fully assembled — the drop-in
    /// replacement for `recv(..).into_matrix()` wherever the consumer
    /// needs the whole matrix before computing. The assembly copy is
    /// free, like a monolithic receive's buffer hand-off; the clock still
    /// advances chunk by chunk, so the wire-time accounting matches the
    /// sender's per-chunk stamps.
    pub fn recv_matrix(&mut self, src: usize, tag: Tag) -> Matrix {
        let mut s = self.open_stream(src, tag);
        let mut full: Option<Matrix> = None;
        while let Some((band, chunk)) = s.next(self) {
            if band.start == 0 && band.end == s.rows {
                return chunk;
            }
            let buf = full.get_or_insert_with(|| Matrix::zeros(s.rows, s.cols));
            buf.set_rows(band.start, &chunk);
        }
        full.unwrap_or_else(|| Matrix::zeros(s.rows, s.cols))
    }

    /// Send a request to machine `dst`'s *service plane* (its feature
    /// server thread, if one is running — see `spawn_server`). A
    /// transport fault boundary (`net::fault`).
    pub fn send_service(&mut self, dst: usize, tag: Tag, payload: Payload) {
        net::fault::step(self.rank, net::fault::FaultPoint::ServiceSend);
        let bytes = payload.nbytes();
        let ready_at = self.links.schedule(self.rank, dst, self.clock, bytes);
        self.metrics.bytes_sent += bytes;
        self.metrics.msgs_sent += 1;
        let msg = Message { src: self.rank, tag: tag.0, ready_at, payload };
        self.service_senders[dst].send(msg).expect("service receiver hung up");
    }

    /// Detach the service plane and run `server` on it in a scoped thread
    /// while `body` runs on this context. The server models the RPC /
    /// feature-server thread every distributed GNN system runs alongside
    /// compute (it has its own simulated clock; real systems use spare
    /// cores for it). Afterwards, the server's metrics merge into this
    /// machine's and the clock advances to `max(main, server)`.
    pub fn with_server<T, S>(
        &mut self,
        server: S,
        body: impl FnOnce(&mut Ctx) -> T,
    ) -> T
    where
        S: FnOnce(&mut ServerCtx) + Send,
        T: Send,
    {
        let inbox = self
            .service_inbox
            .take()
            .expect("service plane already taken (nested with_server?)");
        // The server thread inherits the caller's chunk granularity and
        // storage knobs so its responses follow the same pipelining and
        // paging configuration (thread-locals do not cross the spawn on
        // their own).
        let chunk = net::chunk_rows();
        let budget = crate::storage::mem_budget();
        let page_rows = crate::storage::page_rows();
        let ring_dir = collectives::ring_dir();
        let plan = crate::runtime::autotune::current_plan();
        let mut sctx = ServerCtx {
            rank: self.rank,
            world: self.world,
            clock: self.clock,
            cores: self.cores,
            senders: self.senders.clone(),
            inbox,
            stash: std::mem::take(&mut self.service_stash),
            links: Arc::clone(&self.links),
            metrics: MachineMetrics::default(),
        };
        let (out, sctx) = std::thread::scope(|scope| {
            let handle = scope.spawn(move || {
                net::with_chunk_rows(chunk, || {
                    collectives::with_ring_dir(ring_dir, || {
                        crate::runtime::autotune::with_plan(plan, || {
                            crate::storage::with_mem_budget(budget, || {
                                crate::storage::with_page_rows(page_rows, || server(&mut sctx))
                            })
                        })
                    })
                });
                sctx
            });
            let out = body(self);
            (out, handle.join().expect("server thread panicked"))
        });
        // Merge: the server ran concurrently on the same machine.
        self.clock = self.clock.max(sctx.clock);
        self.metrics.bytes_sent += sctx.metrics.bytes_sent;
        self.metrics.bytes_recv += sctx.metrics.bytes_recv;
        self.metrics.msgs_sent += sctx.metrics.msgs_sent;
        self.metrics.msgs_recv += sctx.metrics.msgs_recv;
        self.metrics.chunks_sent += sctx.metrics.chunks_sent;
        self.metrics.chunks_recv += sctx.metrics.chunks_recv;
        self.metrics.sim_serve_secs += sctx.metrics.sim_compute_secs;
        self.service_inbox = Some(sctx.inbox);
        self.service_stash = sctx.stash;
        out
    }

    /// Synchronize all machines and align clocks to the global maximum
    /// (models a blocking collective fence).
    pub fn barrier(&mut self) {
        {
            let mut mx = self.barrier_clock.lock().unwrap();
            if self.clock > *mx {
                *mx = self.clock;
            }
        }
        self.barrier.wait();
        self.clock = *self.barrier_clock.lock().unwrap();
        self.barrier.wait();
        // One designated machine resets the shared max for the next fence.
        if self.rank == 0 {
            *self.barrier_clock.lock().unwrap() = 0.0;
        }
        self.barrier.wait();
    }
}

/// A chunked matrix transfer being received (see [`Ctx::open_stream`]).
///
/// Tracks how many chunks remain and which row the next band starts at;
/// the data itself is pulled through the owning [`Ctx`] so clocks and
/// byte counters stay on the machine doing the receiving.
pub struct MatrixStream {
    src: usize,
    tag: Tag,
    /// Total rows the transfer delivers.
    rows: usize,
    /// Column count of every chunk.
    cols: usize,
    next_row: usize,
    chunks_left: usize,
    /// Monolithic payload already pulled from the inbox by `open_stream`.
    whole: Option<Matrix>,
}

impl MatrixStream {
    /// Total rows the stream will deliver.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count of every chunk.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// True once every chunk has been delivered by [`MatrixStream::next`].
    pub fn done(&self) -> bool {
        self.whole.is_none() && self.chunks_left == 0
    }

    /// Pull the next chunk, advancing `ctx`'s clock to its completion
    /// stamp: returns the row band it covers in the full matrix plus its
    /// data, or `None` when the transfer is complete.
    pub fn next(&mut self, ctx: &mut Ctx) -> Option<(std::ops::Range<usize>, Matrix)> {
        if let Some(m) = self.whole.take() {
            self.next_row = self.rows;
            return Some((0..self.rows, m));
        }
        if self.chunks_left == 0 {
            return None;
        }
        let m = ctx.recv(self.src, self.tag).into_matrix();
        let lo = self.next_row;
        let hi = lo + m.rows;
        assert!(hi <= self.rows, "chunk overruns transfer ({} > {})", hi, self.rows);
        assert_eq!(m.cols, self.cols, "chunk width changed mid-transfer");
        self.next_row = hi;
        self.chunks_left -= 1;
        if self.chunks_left == 0 {
            assert_eq!(self.next_row, self.rows, "chunked transfer under-delivered");
        }
        Some((lo..hi, m))
    }
}

/// The context a feature-server thread runs on (see `Ctx::with_server`):
/// it receives requests in arrival order from the machine's service plane,
/// performs gathers (clocked like `Ctx::compute`), and replies on the data
/// plane.
pub struct ServerCtx {
    /// Rank of the machine this server thread belongs to.
    pub rank: usize,
    /// Number of simulated machines in the cluster.
    pub world: usize,
    clock: f64,
    cores: f64,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    /// Early messages belonging to later phases.
    stash: std::collections::VecDeque<Message>,
    links: Arc<LinkTable>,
    /// Counters merged into the owning machine's after the server joins.
    pub metrics: MachineMetrics,
}

impl ServerCtx {
    /// Receive the next request *for this phase* (tag high half), in
    /// arrival order; messages for other phases are stashed for the next
    /// server. A fast peer may already be issuing the next primitive's
    /// requests while this server drains the current one.
    pub fn recv_any(&mut self, phase: u32) -> Message {
        if let Some(pos) = self
            .stash
            .iter()
            .position(|m| (m.tag >> 32) as u32 == phase)
        {
            let msg = self.stash.remove(pos).unwrap();
            self.clock = self.clock.max(msg.ready_at);
            self.metrics.bytes_recv += msg.payload.nbytes();
            self.metrics.msgs_recv += 1;
            return msg;
        }
        loop {
            let msg = self.inbox.recv().expect("service channel closed");
            if msg.tag == net::POISON_TAG {
                std::panic::resume_unwind(Box::new(PeerDied { src: msg.src }));
            }
            if (msg.tag >> 32) as u32 != phase {
                self.stash.push_back(msg);
                continue;
            }
            self.clock = self.clock.max(msg.ready_at);
            self.metrics.bytes_recv += msg.payload.nbytes();
            self.metrics.msgs_recv += 1;
            return msg;
        }
    }

    /// Advance the server clock by an explicit duration (modeled costs —
    /// e.g. simulated spill-device I/O from `crate::storage`).
    pub fn advance(&mut self, secs: f64) {
        self.clock += secs;
        self.metrics.sim_compute_secs += secs;
    }

    /// Run `f`, advancing the server clock by its scaled total CPU time
    /// (same thread-aware accounting as `Ctx::compute`).
    pub fn compute<T>(&mut self, f: impl FnOnce() -> T) -> T {
        crate::runtime::par::take_child_accounting(); // clear stale ledger
        let t0 = thread_cpu_time();
        let v = f();
        let main = (thread_cpu_time() - t0).max(0.0);
        let (child, forks) = crate::runtime::par::take_child_accounting();
        let dt =
            crate::primitives::costs::intra_rank_compute_secs(main + child, forks, self.cores);
        self.clock += dt;
        self.metrics.sim_compute_secs += dt;
        v
    }

    /// Reply to `dst` on its data plane.
    pub fn send(&mut self, dst: usize, tag: Tag, payload: Payload) {
        let bytes = payload.nbytes();
        let ready_at = self.links.schedule(self.rank, dst, self.clock, bytes);
        self.metrics.bytes_sent += bytes;
        self.metrics.msgs_sent += 1;
        let msg = Message { src: self.rank, tag: tag.0, ready_at, payload };
        self.senders[dst].send(msg).expect("receiver hung up");
    }

    /// Reply with `m` as a pipelined chunk sequence — the server-side
    /// twin of [`Ctx::send_chunked`] (one shared protocol definition,
    /// `net::chunk_plan`); requesters consume the bands with
    /// [`Ctx::recv_stream`] / [`Ctx::recv_matrix`]. This is how the
    /// feature servers stream gathered rows so the requester's per-band
    /// aggregation overlaps the rest of the response.
    pub fn send_chunked(&mut self, dst: usize, tag: Tag, m: Matrix) {
        match net::chunk_plan(m.rows, m.cols) {
            None => self.send(dst, tag, Payload::Matrix(m)),
            Some((header, bounds)) => {
                self.metrics.chunks_sent += (bounds.len() - 1) as u64;
                self.send_control(dst, tag, Payload::U32(header));
                for w in bounds.windows(2) {
                    self.send(dst, tag, Payload::Matrix(m.slice_rows(w[0], w[1])));
                }
            }
        }
    }

    /// Send a control message that consumes no link time (see
    /// `Ctx::send_control`): in-band frame metadata, bytes still counted.
    fn send_control(&mut self, dst: usize, tag: Tag, payload: Payload) {
        let bytes = payload.nbytes();
        self.metrics.bytes_sent += bytes;
        self.metrics.msgs_sent += 1;
        let msg = Message { src: self.rank, tag: tag.0, ready_at: self.clock, payload };
        self.senders[dst].send(msg).expect("receiver hung up");
    }

    /// Current simulated time on this server thread.
    pub fn now(&self) -> f64 {
        self.clock
    }
}

/// Thread CPU time in seconds (CLOCK_THREAD_CPUTIME_ID), so compute costs
/// are unaffected by how many simulated machines share the physical cores.
pub fn thread_cpu_time() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    assert_eq!(rc, 0, "clock_gettime failed");
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// A simulated cluster: spawns one thread per machine, runs `f` on each,
/// and collects results plus per-machine metrics into a `ClusterReport`.
pub struct Cluster {
    /// Number of simulated machines.
    pub world: usize,
    /// Link model shared by every machine pair.
    pub net: NetConfig,
    /// Cores per simulated machine (compute-time divisor). Default 64 —
    /// the paper's 64-vCPU R5.16xlarge machines.
    pub cores: f64,
    /// Membership epoch this run is fenced at (stamped into any
    /// [`RankFailed`] the run surfaces). 0 for fixed-world runs.
    pub epoch: u64,
}

impl Cluster {
    /// A cluster of `world` machines over `net`-modeled links.
    pub fn new(world: usize, net: NetConfig) -> Self {
        assert!(world >= 1);
        Cluster { world, net, cores: 64.0, epoch: 0 }
    }

    /// Override the per-machine core count (compute-time divisor).
    pub fn with_cores(mut self, cores: f64) -> Self {
        assert!(cores >= 1.0);
        self.cores = cores;
        self
    }

    /// Fence this run at membership epoch `epoch` — failures it surfaces
    /// carry the epoch, so a reconfiguration driver can tell which
    /// transition a dead rank belonged to.
    pub fn at_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self
    }

    /// Run `f(rank_ctx)` on every machine; returns per-rank values and the
    /// cluster report. `f` must be deterministic per rank for reproducible
    /// metrics.
    pub fn run<T, F>(&self, f: F) -> Result<(Vec<T>, ClusterReport)>
    where
        T: Send + 'static,
        F: Fn(&mut Ctx) -> T + Send + Sync + 'static,
    {
        let world = self.world;
        let links = Arc::new(LinkTable::new(world, self.net));
        let barrier = Arc::new(Barrier::new(world));
        let barrier_clock = Arc::new(Mutex::new(0.0f64));
        let f = Arc::new(f);

        let mut senders: Vec<Sender<Message>> = Vec::with_capacity(world);
        let mut service_senders: Vec<Sender<Message>> = Vec::with_capacity(world);
        let mut inboxes: Vec<Option<Receiver<Message>>> = Vec::with_capacity(world);
        let mut service_inboxes: Vec<Option<Receiver<Message>>> = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = std::sync::mpsc::channel();
            senders.push(tx);
            inboxes.push(Some(rx));
            let (stx, srx) = std::sync::mpsc::channel();
            service_senders.push(stx);
            service_inboxes.push(Some(srx));
        }

        let mut handles = Vec::with_capacity(world);
        // Ranks are real OS threads, so each gets an equal slice of the
        // intra-rank kernel pool (min 1): world-wide fan-out never exceeds
        // the configured pool size, and a sim with ranks >= cores runs its
        // kernels serially instead of oversubscribing the host (which
        // would inflate every measured thread-CPU time). Thread count
        // never changes results — only scheduling.
        let rank_pool = (crate::runtime::par::num_threads() / world).max(1);
        // Rank threads inherit the caller's chunk granularity and storage
        // knobs (thread locals don't cross spawns), so `with_chunk_rows` /
        // `with_mem_budget` / `with_page_rows` sweeps in tests/benches
        // reach every simulated machine.
        let chunk = net::chunk_rows();
        let budget = crate::storage::mem_budget();
        let page_rows = crate::storage::page_rows();
        let ring_dir = collectives::ring_dir();
        let plan = crate::runtime::autotune::current_plan();
        let fault_spec = net::fault::capture();
        for rank in 0..world {
            let senders = senders.clone();
            let service_senders = service_senders.clone();
            let inbox = inboxes[rank].take().unwrap();
            let service_inbox = service_inboxes[rank].take().unwrap();
            let links = Arc::clone(&links);
            let barrier = Arc::clone(&barrier);
            let barrier_clock = Arc::clone(&barrier_clock);
            let f = Arc::clone(&f);
            let cores = self.cores;
            let fault_spec = fault_spec.clone();
            let plan = plan.clone();
            handles.push(std::thread::spawn(move || {
                net::fault::install(fault_spec);
                let mut ctx = Ctx {
                    rank,
                    world,
                    clock: 0.0,
                    cores,
                    senders,
                    service_senders,
                    inbox,
                    service_inbox: Some(service_inbox),
                    service_stash: std::collections::VecDeque::new(),
                    stash: HashMap::new(),
                    links,
                    barrier,
                    barrier_clock,
                    mem: MemTracker::default(),
                    metrics: MachineMetrics::default(),
                };
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    net::with_chunk_rows(chunk, || {
                        collectives::with_ring_dir(ring_dir, || {
                            crate::runtime::autotune::with_plan(plan, || {
                                crate::storage::with_mem_budget(budget, || {
                                    crate::storage::with_page_rows(page_rows, || {
                                        crate::runtime::par::with_threads(rank_pool, || {
                                            f(&mut ctx)
                                        })
                                    })
                                })
                            })
                        })
                    })
                }));
                if let Err(payload) = &result {
                    // A dead machine must not starve peers blocked in
                    // `recv`: poison both planes so they abort (see
                    // `PeerDied`) instead of stalling. Injected kills and
                    // collateral aborts are expected under fault sweeps;
                    // only organic panics get announced.
                    if !payload.is::<net::fault::RankKilled>() && !payload.is::<net::PeerDied>() {
                        eprintln!("[cluster] machine {} panicked", rank);
                    }
                    for dst in 0..world {
                        if dst == rank {
                            continue;
                        }
                        let poison = || Message {
                            src: rank,
                            tag: net::POISON_TAG,
                            ready_at: ctx.clock,
                            payload: Payload::Empty,
                        };
                        let _ = ctx.senders[dst].send(poison());
                        let _ = ctx.service_senders[dst].send(poison());
                    }
                }
                // End-of-run rendezvous: nobody drops its channels until
                // every machine has finished its body, otherwise a fast
                // machine's exit would break slower peers' sends.
                ctx.barrier.wait();
                (result, ctx.clock, ctx.metrics, ctx.mem)
            }));
        }

        let mut values = Vec::with_capacity(world);
        let mut report = ClusterReport::new(world);
        // Classification: an injected kill is always the root cause; an
        // organic panic is the root cause among organic panics (lowest
        // rank wins); `PeerDied` aborts are collateral of whichever rank
        // poisoned them and are never reported as failures of their own.
        let mut injected: Option<RankFailed> = None;
        let mut organic: Option<RankFailed> = None;
        for (rank, h) in handles.into_iter().enumerate() {
            let (result, clock, metrics, mem) = h
                .join()
                .map_err(|_| anyhow::anyhow!("machine {} thread died outside its body", rank))?;
            report.record(rank, clock, metrics, mem);
            match result {
                Ok(v) => values.push(v),
                Err(payload) => {
                    if let Some(k) = payload.downcast_ref::<net::fault::RankKilled>() {
                        injected.get_or_insert(RankFailed {
                            rank: k.rank,
                            epoch: self.epoch,
                            point: Some(k.point.name()),
                            ordinal: k.ordinal,
                        });
                    } else if !payload.is::<net::PeerDied>() {
                        organic.get_or_insert(RankFailed {
                            rank,
                            epoch: self.epoch,
                            point: None,
                            ordinal: 0,
                        });
                    }
                }
            }
        }
        if let Some(failed) = injected.or(organic) {
            return Err(anyhow::Error::new(failed));
        }
        anyhow::ensure!(
            values.len() == world,
            "every rank aborted as collateral with no root failure (poison without a source)"
        );
        Ok((values, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_net() -> NetConfig {
        NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 }
    }

    #[test]
    fn ping_pong_advances_clocks() {
        let cluster = Cluster::new(2, small_net());
        let (vals, report) = cluster
            .run(|ctx| {
                let tag = Tag(1);
                if ctx.rank == 0 {
                    ctx.send(1, tag, Payload::U32(vec![7; 1000]));
                    let p = ctx.recv(1, tag);
                    p.nbytes()
                } else {
                    let p = ctx.recv(0, tag);
                    ctx.send(0, tag, Payload::U32(vec![9; 1000]));
                    p.nbytes()
                }
            })
            .unwrap();
        assert_eq!(vals, vec![4064, 4064]); // 4000 data + 64 header
        // two serialized transfers: makespan >= 2 * (latency + bytes/bw)
        let per = 100e-6 + 4064.0 * 8.0 / (25e9);
        assert!(report.makespan() >= 2.0 * per * 0.99, "makespan={}", report.makespan());
        assert_eq!(report.total_bytes(), 8128);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let cluster = Cluster::new(2, small_net());
        let (vals, _) = cluster
            .run(|ctx| {
                if ctx.rank == 0 {
                    ctx.send(1, Tag(1), Payload::U32(vec![1]));
                    ctx.send(1, Tag(2), Payload::U32(vec![2]));
                    0
                } else {
                    // receive in reverse tag order
                    let b = match ctx.recv(0, Tag(2)) {
                        Payload::U32(v) => v[0],
                        _ => panic!(),
                    };
                    let a = match ctx.recv(0, Tag(1)) {
                        Payload::U32(v) => v[0],
                        _ => panic!(),
                    };
                    (a * 10 + b) as usize
                }
            })
            .unwrap();
        assert_eq!(vals[1], 12);
    }

    #[test]
    fn overlap_is_credited() {
        // Machine 1 computes while the transfer is in flight; its final
        // clock should be ~max(compute, transfer), not the sum.
        let bytes: u64 = 32 * 1024 * 1024; // 32 MiB over 25 Gbps ≈ 10.7 ms
        let net = small_net();
        let xfer = 100e-6 + bytes as f64 * 8.0 / 25e9;
        let cluster = Cluster::new(2, net);
        let (_, report) = cluster
            .run(move |ctx| {
                if ctx.rank == 0 {
                    ctx.send(1, Tag(1), Payload::Bytes(vec![0u8; bytes as usize]));
                } else {
                    // busy-work approximately comparable to the transfer
                    ctx.compute(|| {
                        let mut acc = 0u64;
                        for i in 0..2_000_000u64 {
                            acc = acc.wrapping_add(i * i);
                        }
                        std::hint::black_box(acc);
                    });
                    let _ = ctx.recv(0, Tag(1));
                }
            })
            .unwrap();
        let m1 = &report.machines[1];
        let total = m1.sim_compute_secs + m1.sim_comm_wait_secs;
        // wait should be at most the transfer time (overlap credited)
        assert!(
            m1.sim_comm_wait_secs <= xfer * 1.05,
            "wait={} xfer={}",
            m1.sim_comm_wait_secs,
            xfer
        );
        assert!(total > 0.0);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let cluster = Cluster::new(4, small_net());
        let (clocks, _) = cluster
            .run(|ctx| {
                ctx.advance(ctx.rank as f64); // ranks at t=0,1,2,3
                ctx.barrier();
                ctx.now()
            })
            .unwrap();
        for c in &clocks {
            assert!((c - 3.0).abs() < 1e-9, "clocks={:?}", clocks);
        }
    }

    #[test]
    fn chunked_send_recv_roundtrip() {
        // 100 rows at 16-row chunks → 7 chunks behind a header message.
        net::with_chunk_rows(16, || {
            let cluster = Cluster::new(2, small_net());
            let (vals, report) = cluster
                .run(|ctx| {
                    if ctx.rank == 0 {
                        let mut m = Matrix::zeros(100, 8);
                        for (i, v) in m.data.iter_mut().enumerate() {
                            *v = i as f32;
                        }
                        ctx.send_chunked(1, Tag(9), m.clone());
                        m
                    } else {
                        ctx.recv_matrix(0, Tag(9))
                    }
                })
                .unwrap();
            assert_eq!(vals[0], vals[1], "assembled receive must be bit-identical");
            assert_eq!(report.machines[0].chunks_sent, 7);
            assert_eq!(report.machines[1].chunks_recv, 7);
            assert_eq!(report.machines[1].msgs_recv, 8, "header + 7 chunks");
        });
    }

    #[test]
    fn monolithic_fallback_sends_one_message() {
        net::with_chunk_rows(0, || {
            let cluster = Cluster::new(2, small_net());
            let (_, report) = cluster
                .run(|ctx| {
                    if ctx.rank == 0 {
                        ctx.send_chunked(1, Tag(3), Matrix::zeros(100, 8));
                    } else {
                        let m = ctx.recv_matrix(0, Tag(3));
                        assert_eq!((m.rows, m.cols), (100, 8));
                    }
                })
                .unwrap();
            assert_eq!(report.machines[0].msgs_sent, 1);
            assert_eq!(report.machines[0].chunks_sent, 0);
        });
    }

    #[test]
    fn chunked_overlap_beats_monolithic() {
        // Deterministic overlap check: the receiver charges exactly one
        // row's wire time of compute per row (`advance`), so at chunk
        // granularity the step pipelines to ~max(comm, compute) while the
        // monolithic path serializes to comm + compute.
        let rows = 64usize;
        let cols = 256usize;
        let net_cfg = NetConfig { bandwidth_gbps: 1.0, latency_secs: 1e-6 };
        let per_row = (cols as f64 * 4.0 * 8.0) / 1e9;
        let run = |chunk: usize| -> f64 {
            net::with_chunk_rows(chunk, || {
                let cluster = Cluster::new(2, net_cfg);
                let (_, rep) = cluster
                    .run(move |ctx| {
                        if ctx.rank == 0 {
                            ctx.send_chunked(1, Tag(1), Matrix::zeros(rows, cols));
                        } else {
                            ctx.recv_stream(0, Tag(1), |ctx, band, _m| {
                                ctx.advance(band.len() as f64 * per_row);
                            });
                        }
                    })
                    .unwrap();
                rep.makespan()
            })
        };
        let mono = run(0);
        let piped = run(8);
        assert!(piped < mono * 0.75, "piped={} mono={}", piped, mono);
    }

    #[test]
    fn compute_uses_cpu_time() {
        let cluster = Cluster::new(2, small_net());
        let (_, report) = cluster
            .run(|ctx| {
                ctx.compute(|| {
                    let mut acc = 0f64;
                    for i in 0..200_000 {
                        acc += (i as f64).sqrt();
                    }
                    std::hint::black_box(acc);
                });
            })
            .unwrap();
        for m in &report.machines {
            assert!(m.sim_compute_secs > 0.0);
        }
    }
}
