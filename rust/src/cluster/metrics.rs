//! Per-machine and cluster-level metrics: simulated time split into compute
//! and communication wait, byte/message counters, and peak memory. Benches
//! read these to print the paper's comm/compute split (Figs. 17–19) and the
//! Table 1–3 byte validations.

use super::memory::MemTracker;
use crate::util::{human_bytes, human_secs};

/// Structured failure of one simulated machine, surfaced by
/// `Cluster::run` instead of a join-handle panic: the rank that failed
/// and the membership epoch the cluster was fenced at
/// (`Cluster::at_epoch`). Injected transport kills (`net::fault`) carry
/// their boundary name and ordinal; organic panics carry neither.
/// Downcast via [`RankFailed::find`].
#[derive(Clone, Debug)]
pub struct RankFailed {
    /// Rank of the machine whose body failed.
    pub rank: usize,
    /// Membership epoch the run was fenced at (0 when the caller never
    /// set one).
    pub epoch: u64,
    /// Transport boundary an injected kill fired at, `None` for an
    /// organic panic.
    pub point: Option<&'static str>,
    /// 1-based boundary ordinal for injected kills (0 for organic).
    pub ordinal: u64,
}

impl std::fmt::Display for RankFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.point {
            Some(p) => write!(
                f,
                "rank {} failed at membership epoch {} (killed at {} boundary #{})",
                self.rank, self.epoch, p, self.ordinal
            ),
            None => write!(f, "rank {} failed at membership epoch {} (panicked)", self.rank, self.epoch),
        }
    }
}

impl std::error::Error for RankFailed {}

impl RankFailed {
    /// The `RankFailed` in `err`'s chain, if any — how failure tests
    /// assert on rank and epoch without string matching.
    pub fn find(err: &anyhow::Error) -> Option<&RankFailed> {
        err.chain().find_map(|c| c.downcast_ref())
    }
}

/// Out-of-core tiered-storage counters (see `crate::storage`): one set per
/// machine, absorbed from that rank's `PageCache` scopes. Byte counts are
/// spill-device traffic; `peak_resident_bytes` is the cache's high-water
/// mark (bounded by the budget plus one in-flight page per stream).
#[derive(Clone, Debug, Default)]
pub struct StorageCounters {
    /// Pages faulted in from the spill device (cache misses).
    pub page_faults: u64,
    /// Pages evicted to stay under the byte budget.
    pub evictions: u64,
    /// Bytes written to the spill device (staging + dirty write-back).
    pub spill_bytes_written: u64,
    /// Bytes read back from the spill device (faults).
    pub spill_bytes_read: u64,
    /// High-water mark of cache-resident bytes.
    pub peak_resident_bytes: u64,
    /// Effective byte budget the cache ran under (0 = unbounded).
    pub budget_bytes: u64,
    /// Bytes appended (and fsync'd) to the durable write-ahead log.
    pub wal_bytes: u64,
    /// Checkpoints written by the durable store (create + compactions).
    pub checkpoints: u64,
    /// Recoveries performed (log-over-checkpoint replays on open).
    pub recoveries: u64,
}

impl StorageCounters {
    /// Fold another scope's counters in: traffic adds, peaks/budgets max.
    pub fn add(&mut self, other: &StorageCounters) {
        self.page_faults += other.page_faults;
        self.evictions += other.evictions;
        self.spill_bytes_written += other.spill_bytes_written;
        self.spill_bytes_read += other.spill_bytes_read;
        self.peak_resident_bytes = self.peak_resident_bytes.max(other.peak_resident_bytes);
        self.budget_bytes = self.budget_bytes.max(other.budget_bytes);
        self.wal_bytes += other.wal_bytes;
        self.checkpoints += other.checkpoints;
        self.recoveries += other.recoveries;
    }
}

/// Per-service-class request counters — the serving-tier axis (one set
/// per `serve::RequestClass`), as opposed to the per-machine axis of
/// [`MachineMetrics`]. `ServePool` keeps one per class and the traffic
/// harness (`traffic::replay`, `deal traffic`) reports SLO gates over
/// them; the invariant the overload tests pin is conservation:
/// `submitted == served + rejected + failed` once a workload drains —
/// overload *rejects*, it never silently drops.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceClassCounters {
    /// Requests offered to admission control.
    pub submitted: u64,
    /// Requests answered successfully.
    pub served: u64,
    /// Requests shed (queue full, id out of range, stale after shrink).
    pub rejected: u64,
    /// Requests answered with an error.
    pub failed: u64,
}

impl ServiceClassCounters {
    /// Fold another window's counters in.
    pub fn add(&mut self, other: &ServiceClassCounters) {
        self.submitted += other.submitted;
        self.served += other.served;
        self.rejected += other.rejected;
        self.failed += other.failed;
    }

    /// Counters accumulated since `mark` (element-wise difference; the
    /// mark must be an earlier snapshot of the same counter set).
    pub fn since(&self, mark: &ServiceClassCounters) -> ServiceClassCounters {
        ServiceClassCounters {
            submitted: self.submitted - mark.submitted,
            served: self.served - mark.served,
            rejected: self.rejected - mark.rejected,
            failed: self.failed - mark.failed,
        }
    }

    /// `served + rejected + failed` — equals `submitted` once every
    /// admitted request has been answered (the conservation invariant).
    pub fn accounted(&self) -> u64 {
        self.served + self.rejected + self.failed
    }
}

/// Counters accumulated by one simulated machine.
#[derive(Clone, Debug, Default)]
pub struct MachineMetrics {
    /// Bytes put on the wire (payload + per-message envelope).
    pub bytes_sent: u64,
    /// Bytes received off the wire.
    pub bytes_recv: u64,
    /// Messages sent (a chunked transfer counts one per chunk + header).
    pub msgs_sent: u64,
    /// Messages received.
    pub msgs_recv: u64,
    /// Row-band chunks sent by pipelined transfers (`Ctx::send_chunked`);
    /// monolithic-fallback sends don't count.
    pub chunks_sent: u64,
    /// Row-band chunks received from pipelined transfers.
    pub chunks_recv: u64,
    /// Simulated seconds spent blocked in `recv` (after overlap credit).
    pub sim_comm_wait_secs: f64,
    /// Simulated seconds of computation (thread-CPU measured).
    pub sim_compute_secs: f64,
    /// Simulated seconds the feature-server thread spent gathering
    /// (concurrent with `sim_compute_secs` — a different core).
    pub sim_serve_secs: f64,
    /// Out-of-core storage counters for this machine (all zero when the
    /// run never opened a paged tier).
    pub storage: StorageCounters,
}

/// Result of one `Cluster::run`.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Per-machine counters, indexed by rank.
    pub machines: Vec<MachineMetrics>,
    /// Each machine's simulated clock at the end of the run.
    pub final_clocks: Vec<f64>,
    /// Each machine's peak tracked memory in bytes.
    pub peak_mem: Vec<u64>,
    /// Full per-machine memory trackers (stage peaks included).
    pub mem: Vec<MemTracker>,
}

impl ClusterReport {
    /// An empty report for a `world`-machine run.
    pub fn new(world: usize) -> Self {
        ClusterReport {
            machines: vec![MachineMetrics::default(); world],
            final_clocks: vec![0.0; world],
            peak_mem: vec![0; world],
            mem: vec![MemTracker::default(); world],
        }
    }

    /// Record one machine's final clock, counters, and memory tracker.
    pub fn record(&mut self, rank: usize, clock: f64, metrics: MachineMetrics, mem: MemTracker) {
        self.final_clocks[rank] = clock;
        self.peak_mem[rank] = mem.peak();
        self.machines[rank] = metrics;
        self.mem[rank] = mem;
    }

    /// Simulated makespan: the slowest machine's final clock.
    pub fn makespan(&self) -> f64 {
        self.final_clocks.iter().cloned().fold(0.0, f64::max)
    }

    /// Total bytes moved over the network (sum of sends; excludes local).
    pub fn total_bytes(&self) -> u64 {
        self.machines.iter().map(|m| m.bytes_sent).sum()
    }

    /// Maximum bytes received by any single machine (the per-machine
    /// communication size the paper's tables bound).
    pub fn max_bytes_recv(&self) -> u64 {
        self.machines.iter().map(|m| m.bytes_recv).max().unwrap_or(0)
    }

    /// Total messages sent over the network (the per-refresh message
    /// count `serve::RefreshReport` surfaces).
    pub fn total_msgs(&self) -> u64 {
        self.machines.iter().map(|m| m.msgs_sent).sum()
    }

    /// Total row-band chunks moved by pipelined transfers (0 when every
    /// transfer fell back to a single monolithic message).
    pub fn total_chunks(&self) -> u64 {
        self.machines.iter().map(|m| m.chunks_sent).sum()
    }

    /// Maximum peak tracked memory on any machine.
    pub fn max_peak_mem(&self) -> u64 {
        self.peak_mem.iter().copied().max().unwrap_or(0)
    }

    /// Total pages faulted in from the spill device across machines.
    pub fn total_page_faults(&self) -> u64 {
        self.machines.iter().map(|m| m.storage.page_faults).sum()
    }

    /// Total spill-device traffic (written + read back) across machines.
    pub fn total_spill_bytes(&self) -> u64 {
        self.machines
            .iter()
            .map(|m| m.storage.spill_bytes_written + m.storage.spill_bytes_read)
            .sum()
    }

    /// Maximum cache-resident high-water mark on any machine.
    pub fn max_storage_resident(&self) -> u64 {
        self.machines
            .iter()
            .map(|m| m.storage.peak_resident_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Total `MemTracker::free` underflow events across machines (0 = the
    /// alloc/free ledgers all balanced).
    pub fn total_underflows(&self) -> u64 {
        self.mem.iter().map(|m| m.underflow_events()).sum()
    }

    /// Total bytes fsync'd into durable write-ahead logs across machines.
    pub fn total_wal_bytes(&self) -> u64 {
        self.machines.iter().map(|m| m.storage.wal_bytes).sum()
    }

    /// Total durable checkpoints written across machines.
    pub fn total_checkpoints(&self) -> u64 {
        self.machines.iter().map(|m| m.storage.checkpoints).sum()
    }

    /// Total durable-store recoveries performed across machines.
    pub fn total_recoveries(&self) -> u64 {
        self.machines.iter().map(|m| m.storage.recoveries).sum()
    }

    /// Total simulated compute across machines.
    pub fn total_compute(&self) -> f64 {
        self.machines.iter().map(|m| m.sim_compute_secs).sum()
    }

    /// Maximum communication wait across machines.
    pub fn max_comm_wait(&self) -> f64 {
        self.machines
            .iter()
            .map(|m| m.sim_comm_wait_secs)
            .fold(0.0, f64::max)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "makespan={} comm={} msgs={} chunks={} compute(max)={} wait(max)={} peak_mem(max)={} faults={} spill={} underflow={} wal={} ckpts={} recov={}",
            human_secs(self.makespan()),
            human_bytes(self.total_bytes()),
            self.total_msgs(),
            self.total_chunks(),
            human_secs(
                self.machines
                    .iter()
                    .map(|m| m.sim_compute_secs)
                    .fold(0.0, f64::max)
            ),
            human_secs(self.max_comm_wait()),
            human_bytes(self.max_peak_mem()),
            self.total_page_faults(),
            human_bytes(self.total_spill_bytes()),
            self.total_underflows(),
            human_bytes(self.total_wal_bytes()),
            self.total_checkpoints(),
            self.total_recoveries(),
        )
    }

    /// Merge another report stage-wise (sequential composition of stages:
    /// clocks add, bytes add, peaks max). Used by the coordinator to
    /// aggregate per-stage cluster runs into an end-to-end report.
    pub fn chain(&mut self, other: &ClusterReport) {
        assert_eq!(self.machines.len(), other.machines.len());
        for i in 0..self.machines.len() {
            self.final_clocks[i] += other.final_clocks[i];
            self.peak_mem[i] = self.peak_mem[i].max(other.peak_mem[i]);
            let a = &mut self.machines[i];
            let b = &other.machines[i];
            a.bytes_sent += b.bytes_sent;
            a.bytes_recv += b.bytes_recv;
            a.msgs_sent += b.msgs_sent;
            a.msgs_recv += b.msgs_recv;
            a.chunks_sent += b.chunks_sent;
            a.chunks_recv += b.chunks_recv;
            a.sim_comm_wait_secs += b.sim_comm_wait_secs;
            a.sim_compute_secs += b.sim_compute_secs;
            a.sim_serve_secs += b.sim_serve_secs;
            a.storage.add(&b.storage);
            self.mem[i].merge_counters(&other.mem[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_class_counters_add_diff_and_conserve() {
        let mut a = ServiceClassCounters { submitted: 10, served: 6, rejected: 3, failed: 1 };
        assert_eq!(a.accounted(), a.submitted, "drained window conserves");
        let mark = a;
        a.add(&ServiceClassCounters { submitted: 5, served: 5, rejected: 0, failed: 0 });
        let w = a.since(&mark);
        assert_eq!(w, ServiceClassCounters { submitted: 5, served: 5, rejected: 0, failed: 0 });
        assert_eq!(a.accounted(), 15);
    }

    #[test]
    fn makespan_is_max_clock() {
        let mut r = ClusterReport::new(3);
        r.final_clocks = vec![1.0, 5.0, 2.0];
        assert_eq!(r.makespan(), 5.0);
    }

    #[test]
    fn chain_adds_clocks_and_maxes_mem() {
        let mut a = ClusterReport::new(2);
        a.final_clocks = vec![1.0, 2.0];
        a.peak_mem = vec![100, 10];
        a.machines[0].bytes_sent = 5;
        let mut b = ClusterReport::new(2);
        b.final_clocks = vec![3.0, 1.0];
        b.peak_mem = vec![50, 80];
        b.machines[0].bytes_sent = 7;
        a.chain(&b);
        assert_eq!(a.final_clocks, vec![4.0, 3.0]);
        assert_eq!(a.peak_mem, vec![100, 80]);
        assert_eq!(a.machines[0].bytes_sent, 12);
        assert_eq!(a.makespan(), 4.0);
    }

    #[test]
    fn total_msgs_sums_sends() {
        let mut r = ClusterReport::new(2);
        r.machines[0].msgs_sent = 3;
        r.machines[1].msgs_sent = 4;
        assert_eq!(r.total_msgs(), 7);
    }

    #[test]
    fn total_chunks_sums_and_chains() {
        let mut a = ClusterReport::new(1);
        a.machines[0].chunks_sent = 5;
        a.machines[0].chunks_recv = 2;
        let mut b = ClusterReport::new(1);
        b.machines[0].chunks_sent = 3;
        a.chain(&b);
        assert_eq!(a.total_chunks(), 8);
        assert_eq!(a.machines[0].chunks_recv, 2);
        assert!(a.summary().contains("chunks=8"));
    }

    #[test]
    fn summary_contains_fields() {
        let r = ClusterReport::new(1);
        let s = r.summary();
        assert!(s.contains("makespan="));
        assert!(s.contains("peak_mem"));
        assert!(s.contains("faults=0"));
        assert!(s.contains("underflow=0"));
    }

    #[test]
    fn storage_counters_chain_and_surface() {
        let mut a = ClusterReport::new(2);
        a.machines[0].storage.page_faults = 3;
        a.machines[0].storage.spill_bytes_written = 100;
        a.machines[0].storage.peak_resident_bytes = 40;
        a.machines[1].storage.page_faults = 1;
        let mut b = ClusterReport::new(2);
        b.machines[0].storage.page_faults = 2;
        b.machines[0].storage.spill_bytes_read = 50;
        b.machines[0].storage.peak_resident_bytes = 30;
        b.machines[0].storage.evictions = 4;
        a.chain(&b);
        assert_eq!(a.total_page_faults(), 6);
        assert_eq!(a.total_spill_bytes(), 150);
        assert_eq!(a.max_storage_resident(), 40, "peaks max, not add");
        assert_eq!(a.machines[0].storage.evictions, 4);
        assert!(a.summary().contains("faults=6"));
    }

    #[test]
    fn durability_counters_chain_and_surface() {
        let mut a = ClusterReport::new(2);
        a.machines[0].storage.wal_bytes = 2048;
        a.machines[0].storage.checkpoints = 2;
        a.machines[1].storage.recoveries = 1;
        let mut b = ClusterReport::new(2);
        b.machines[0].storage.wal_bytes = 1024;
        b.machines[0].storage.checkpoints = 1;
        a.chain(&b);
        assert_eq!(a.total_wal_bytes(), 3072);
        assert_eq!(a.total_checkpoints(), 3);
        assert_eq!(a.total_recoveries(), 1);
        let s = a.summary();
        assert!(s.contains("wal=3.00 KiB"), "{}", s);
        assert!(s.contains("ckpts=3") && s.contains("recov=1"), "{}", s);
    }

    #[test]
    fn underflows_chain_through_mem_trackers() {
        let mut a = ClusterReport::new(1);
        let mut b = ClusterReport::new(1);
        let mut m = MemTracker::default();
        m.free(7); // over-free
        b.record(0, 0.0, MachineMetrics::default(), m);
        assert_eq!(b.total_underflows(), 1);
        a.chain(&b);
        assert_eq!(a.total_underflows(), 1);
        assert!(a.summary().contains("underflow=1"));
    }
}
