//! Per-machine peak-memory accounting.
//!
//! The paper's Fig. 3b argument — graph partitioning alone blows past
//! machine memory; Deal's collaborative partition bounds it — is validated
//! by explicit byte tracking: primitives register tensor allocations and
//! frees, and the tracker records the high-water mark per labelled stage.

use std::collections::HashMap;

/// Tracks current and peak tracked bytes, with optional per-stage peaks.
#[derive(Clone, Debug, Default)]
pub struct MemTracker {
    current: u64,
    peak: u64,
    stage: Option<String>,
    stage_peaks: HashMap<String, u64>,
    /// Times `free` was asked to release more than was tracked. The
    /// subtraction saturates either way; the counter makes the accounting
    /// bug visible instead of silently under-reporting peaks (it surfaces
    /// in `ClusterReport::summary`).
    underflow_events: u64,
}

impl MemTracker {
    /// Register an allocation of `bytes`.
    pub fn alloc(&mut self, bytes: u64) {
        self.current += bytes;
        if self.current > self.peak {
            self.peak = self.current;
        }
        if let Some(stage) = &self.stage {
            let e = self.stage_peaks.entry(stage.clone()).or_insert(0);
            if self.current > *e {
                *e = self.current;
            }
        }
    }

    /// Register a free of `bytes`. Over-freeing saturates to zero in every
    /// build profile and bumps [`MemTracker::underflow_events`] — debug
    /// builds used to assert here while release builds saturated silently;
    /// both now record the same honest counter.
    pub fn free(&mut self, bytes: u64) {
        if bytes > self.current {
            self.underflow_events += 1;
        }
        self.current = self.current.saturating_sub(bytes);
    }

    /// Run `f` accounting a transient buffer of `bytes` for its duration.
    pub fn with_transient<T>(&mut self, bytes: u64, f: impl FnOnce() -> T) -> T {
        self.alloc(bytes);
        let v = f();
        self.free(bytes);
        v
    }

    /// Enter a named stage; subsequent peaks are also recorded under it.
    pub fn enter_stage(&mut self, name: &str) {
        self.stage = Some(name.to_string());
        let cur = self.current;
        let e = self.stage_peaks.entry(name.to_string()).or_insert(0);
        if cur > *e {
            *e = cur;
        }
    }

    /// Leave the current stage (subsequent peaks are global-only).
    pub fn exit_stage(&mut self) {
        self.stage = None;
    }

    /// Currently tracked bytes.
    pub fn current(&self) -> u64 {
        self.current
    }

    /// High-water mark over the tracker's lifetime, in bytes.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Times `free` was asked to release more than was tracked (0 = the
    /// alloc/free ledger balanced).
    pub fn underflow_events(&self) -> u64 {
        self.underflow_events
    }

    /// Fold another tracker's underflow counter into this one (used when
    /// stage reports are chained into an end-to-end report).
    pub fn merge_counters(&mut self, other: &MemTracker) {
        self.underflow_events += other.underflow_events;
    }

    /// Peak bytes recorded while `name` was the active stage (0 if never).
    pub fn stage_peak(&self, name: &str) -> u64 {
        self.stage_peaks.get(name).copied().unwrap_or(0)
    }

    /// All recorded per-stage peaks.
    pub fn stage_peaks(&self) -> &HashMap<String, u64> {
        &self.stage_peaks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut m = MemTracker::default();
        m.alloc(100);
        m.alloc(50);
        m.free(120);
        m.alloc(10);
        assert_eq!(m.current(), 40);
        assert_eq!(m.peak(), 150);
    }

    #[test]
    fn transient_restores_current() {
        let mut m = MemTracker::default();
        m.alloc(10);
        let v = m.with_transient(1000, || 42);
        assert_eq!(v, 42);
        assert_eq!(m.current(), 10);
        assert_eq!(m.peak(), 1010);
    }

    #[test]
    fn stage_peaks_are_separate() {
        let mut m = MemTracker::default();
        m.enter_stage("gemm");
        m.alloc(100);
        m.free(100);
        m.exit_stage();
        m.enter_stage("spmm");
        m.alloc(30);
        m.exit_stage();
        assert_eq!(m.stage_peak("gemm"), 100);
        assert_eq!(m.stage_peak("spmm"), 30);
        assert_eq!(m.stage_peak("missing"), 0);
        assert_eq!(m.peak(), 100);
    }

    #[test]
    fn over_free_counts_underflow_and_saturates() {
        let mut m = MemTracker::default();
        m.alloc(10);
        m.free(25); // 15 more than tracked
        assert_eq!(m.current(), 0);
        assert_eq!(m.underflow_events(), 1);
        m.free(1); // still over-freeing the empty ledger
        assert_eq!(m.underflow_events(), 2);
        m.alloc(5);
        m.free(5); // balanced frees don't count
        assert_eq!(m.underflow_events(), 2);
        let mut sum = MemTracker::default();
        sum.merge_counters(&m);
        sum.merge_counters(&m);
        assert_eq!(sum.underflow_events(), 4);
    }
}
