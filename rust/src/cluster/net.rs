//! Network model: payloads, messages, and the per-link transfer scheduler.
//!
//! Links are directed; each serializes its transfers (one NIC queue per
//! peer). A transfer of `b` bytes issued at sender-time `t` completes at
//! `max(t, link_busy) + latency + b / bandwidth`; `link_busy` advances to
//! that completion time. This is the standard LogP-ish model and is the
//! entire source of "simulated time" on the communication side.

use std::sync::Mutex;

use crate::tensor::Matrix;

/// Network parameters. Defaults mirror the paper's testbed (25 Gbps
/// Ethernet between EC2 instances; 100 µs is a typical same-AZ RTT/2 plus
/// stack overhead).
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    pub bandwidth_gbps: f64,
    pub latency_secs: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 }
    }
}

impl NetConfig {
    /// Seconds to move `bytes` over one link, excluding queueing.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_secs + (bytes as f64 * 8.0) / (self.bandwidth_gbps * 1e9)
    }
}

/// Message tag for matching sends to receives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag(pub u64);

impl Tag {
    /// Compose a tag from a phase id and a sequence number (primitives use
    /// this to keep group communications distinct).
    pub fn of(phase: u32, seq: u32) -> Tag {
        Tag(((phase as u64) << 32) | seq as u64)
    }
}

/// Typed message payloads. Sizes are the *wire* sizes used for byte
/// accounting and transfer-time computation.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Raw bytes.
    Bytes(Vec<u8>),
    /// 32-bit ids (column indices, node ids).
    U32(Vec<u32>),
    /// Flat f32 data (edge values, attention scores).
    F32(Vec<f32>),
    /// A dense matrix (feature tiles).
    Matrix(Matrix),
    /// Empty control message.
    Empty,
}

impl Payload {
    pub fn nbytes(&self) -> u64 {
        const HEADER: u64 = 64; // envelope: src, tag, shape, lengths
        HEADER
            + match self {
                Payload::Bytes(b) => b.len() as u64,
                Payload::U32(v) => 4 * v.len() as u64,
                Payload::F32(v) => 4 * v.len() as u64,
                Payload::Matrix(m) => m.nbytes(),
                Payload::Empty => 0,
            }
    }

    pub fn into_matrix(self) -> Matrix {
        match self {
            Payload::Matrix(m) => m,
            other => panic!("expected Matrix payload, got {:?}", payload_kind(&other)),
        }
    }

    pub fn into_u32(self) -> Vec<u32> {
        match self {
            Payload::U32(v) => v,
            other => panic!("expected U32 payload, got {:?}", payload_kind(&other)),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            other => panic!("expected F32 payload, got {:?}", payload_kind(&other)),
        }
    }
}

fn payload_kind(p: &Payload) -> &'static str {
    match p {
        Payload::Bytes(_) => "Bytes",
        Payload::U32(_) => "U32",
        Payload::F32(_) => "F32",
        Payload::Matrix(_) => "Matrix",
        Payload::Empty => "Empty",
    }
}

/// A message in flight.
pub struct Message {
    pub src: usize,
    pub tag: u64,
    /// Simulated time at which the payload is fully received.
    pub ready_at: f64,
    pub payload: Payload,
}

/// Per-directed-link busy tracking shared by all machines.
pub struct LinkTable {
    world: usize,
    net: NetConfig,
    busy_until: Mutex<Vec<f64>>,
}

impl LinkTable {
    pub fn new(world: usize, net: NetConfig) -> Self {
        LinkTable { world, net, busy_until: Mutex::new(vec![0.0; world * world]) }
    }

    /// Schedule a transfer; returns its completion (ready) time.
    pub fn schedule(&self, src: usize, dst: usize, sender_now: f64, bytes: u64) -> f64 {
        if src == dst {
            // Local move: modeled as free (it is a pointer hand-off in a
            // real system too — same machine, no NIC).
            return sender_now;
        }
        let idx = src * self.world + dst;
        let mut busy = self.busy_until.lock().unwrap();
        let start = busy[idx].max(sender_now);
        let done = start + self.net.transfer_secs(bytes);
        busy[idx] = done;
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let net = NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 };
        let t = net.transfer_secs(25_000_000_000 / 8); // 1 second of bytes
        assert!((t - 1.0001).abs() < 1e-9);
    }

    #[test]
    fn link_serializes_transfers() {
        let net = NetConfig { bandwidth_gbps: 1.0, latency_secs: 0.0 };
        let links = LinkTable::new(2, net);
        let b = 1_000_000_000 / 8; // 1 second each
        let t1 = links.schedule(0, 1, 0.0, b);
        let t2 = links.schedule(0, 1, 0.0, b);
        assert!((t1 - 1.0).abs() < 1e-9);
        assert!((t2 - 2.0).abs() < 1e-9, "second transfer must queue");
        // opposite direction is an independent link
        let t3 = links.schedule(1, 0, 0.0, b);
        assert!((t3 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn local_transfer_is_free() {
        let links = LinkTable::new(2, NetConfig::default());
        assert_eq!(links.schedule(0, 0, 5.0, 1 << 30), 5.0);
    }

    #[test]
    fn payload_sizes() {
        assert_eq!(Payload::U32(vec![0; 10]).nbytes(), 64 + 40);
        assert_eq!(Payload::F32(vec![0.0; 10]).nbytes(), 64 + 40);
        assert_eq!(Payload::Empty.nbytes(), 64);
        let m = Matrix::zeros(3, 4);
        assert_eq!(Payload::Matrix(m).nbytes(), 64 + 48);
    }

    #[test]
    fn tag_composition() {
        let t = Tag::of(3, 7);
        assert_eq!(t.0, (3u64 << 32) | 7);
        assert_ne!(Tag::of(3, 7), Tag::of(7, 3));
    }
}
