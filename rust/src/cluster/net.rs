//! Network model: payloads, messages, and the per-link transfer scheduler.
//!
//! Links are directed; each serializes its transfers (one NIC queue per
//! peer). A transfer of `b` bytes issued at sender-time `t` completes at
//! `max(t, link_busy) + latency + b / bandwidth`; `link_busy` advances to
//! that completion time. This is the standard LogP-ish model and is the
//! entire source of "simulated time" on the communication side.
//!
//! **Chunked, pipelined transfers** (paper §4, DESIGN.md
//! §Pipelined-communication): a large matrix can be sent as a sequence of
//! row-band chunks (`Ctx::send_chunked`), each scheduled on the link as
//! its own transfer with its own completion stamp. The receiver consumes
//! bands as they land (`Ctx::recv_stream`), so compute on early rows
//! overlaps the tail of the transfer. The granularity knob lives here:
//! [`chunk_rows`] resolves `with_chunk_rows` scope → `set_chunk_rows`
//! global (`pipeline.chunk_rows` config / `--chunk-rows` CLI) →
//! `DEAL_CHUNK_ROWS` env → [`DEFAULT_CHUNK_ROWS`]; `0` disables chunking
//! (monolithic single-message transfers, the pre-pipelining behavior).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::tensor::Matrix;

/// Default rows per chunk for pipelined matrix transfers (see
/// [`chunk_rows`]): a compromise between fill-time reduction and the
/// per-chunk latency the link model charges — 256 rows of a 128-wide f32
/// tile is 128 KiB, a handful of chunks for typical tile exchanges, near
/// the `k* = sqrt(overlap/latency)` optimum of
/// `primitives::costs::optimal_chunks` at bench scales.
pub const DEFAULT_CHUNK_ROWS: usize = 256;

/// Sentinel for "no override" in the chunk-rows resolution chain (`0` is a
/// meaningful value — monolithic — so unset needs its own marker).
const CHUNK_UNSET: usize = usize::MAX;

/// Process-global chunk-rows override; `CHUNK_UNSET` means "not set".
static GLOBAL_CHUNK_ROWS: AtomicUsize = AtomicUsize::new(CHUNK_UNSET);

thread_local! {
    /// Thread-local chunk-rows override (`CHUNK_UNSET` = no override).
    static LOCAL_CHUNK_ROWS: Cell<usize> = const { Cell::new(CHUNK_UNSET) };
}

/// Set the process-global pipelined-transfer granularity in rows (`0` =
/// monolithic). Wired to `DealConfig.pipeline.chunk_rows` and the
/// `--chunk-rows` CLI flag; `usize::MAX` resets to auto (env or default).
pub fn set_chunk_rows(n: usize) {
    GLOBAL_CHUNK_ROWS.store(n, Ordering::Relaxed);
}

/// Run `f` with the chunk granularity pinned to `n` rows on this thread
/// (`0` = monolithic). `Cluster::run` and `Ctx::with_server` capture the
/// caller's effective value, so a pinned sweep reaches every simulated
/// machine and its feature-server thread — the chunk-size property tests
/// rely on this.
pub fn with_chunk_rows<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let prev = LOCAL_CHUNK_ROWS.with(|c| c.replace(n));
    let out = f();
    LOCAL_CHUNK_ROWS.with(|c| c.set(prev));
    out
}

/// RAII twin of [`with_chunk_rows`] for call sites that can't wrap a
/// closure — the per-layer autotune overrides in the model forward loops
/// pin the layer's chunk granularity for the rest of the loop body and
/// restore the previous value on drop.
pub struct ChunkRowsGuard {
    prev: usize,
}

impl ChunkRowsGuard {
    /// Pin this thread's chunk granularity to `n` rows until the guard
    /// drops (`0` = monolithic).
    pub fn pin(n: usize) -> ChunkRowsGuard {
        ChunkRowsGuard { prev: LOCAL_CHUNK_ROWS.with(|c| c.replace(n)) }
    }
}

impl Drop for ChunkRowsGuard {
    fn drop(&mut self) {
        LOCAL_CHUNK_ROWS.with(|c| c.set(self.prev));
    }
}

fn env_chunk_default() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DEAL_CHUNK_ROWS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CHUNK_ROWS)
    })
}

/// Effective rows-per-chunk for pipelined transfers issued from this
/// thread: [`with_chunk_rows`] scope → [`set_chunk_rows`] global
/// (config/CLI) → `DEAL_CHUNK_ROWS` env → [`DEFAULT_CHUNK_ROWS`].
/// `0` means monolithic (no chunking). Chunk size never changes results —
/// only simulated schedules (DESIGN.md §Pipelined-communication).
pub fn chunk_rows() -> usize {
    let local = LOCAL_CHUNK_ROWS.with(|c| c.get());
    if local != CHUNK_UNSET {
        return local;
    }
    let global = GLOBAL_CHUNK_ROWS.load(Ordering::Relaxed);
    if global != CHUNK_UNSET {
        return global;
    }
    env_chunk_default()
}

/// Row-band boundaries for a `rows`-row transfer at granularity `chunk`
/// (`0` = one monolithic band). Always returns at least `[0, rows]`, so an
/// empty matrix is one (empty) chunk. Boundaries depend only on the shape
/// and the knob — sender and receiver never need to negotiate.
pub fn chunk_bounds_for(rows: usize, chunk: usize) -> Vec<usize> {
    if chunk == 0 || rows <= chunk {
        return vec![0, rows];
    }
    let mut b: Vec<usize> = (0..rows).step_by(chunk).collect();
    b.push(rows);
    b
}

/// [`chunk_bounds_for`] at this thread's effective [`chunk_rows`].
pub fn chunk_bounds(rows: usize) -> Vec<usize> {
    chunk_bounds_for(rows, chunk_rows())
}

/// The send-side chunking decision for a `rows × cols` matrix, shared by
/// `Ctx::send_chunked` and `ServerCtx::send_chunked` so the wire protocol
/// has exactly one definition: `None` = send monolithically (zero
/// overhead vs. a plain send), `Some((header, bounds))` = announce
/// `bounds.len() - 1` chunks with the 3-word header `[n, rows, cols]`,
/// then ship one row band per entry.
pub(crate) fn chunk_plan(rows: usize, cols: usize) -> Option<(Vec<u32>, Vec<usize>)> {
    let bounds = chunk_bounds(rows);
    let n = bounds.len() - 1;
    if n <= 1 {
        return None;
    }
    Some((vec![n as u32, rows as u32, cols as u32], bounds))
}

/// Tag value reserved for the poison marker a dying rank broadcasts on
/// both planes (see `Cluster::run`): peers blocked in `recv` abort with
/// [`PeerDied`] instead of stalling forever. Real tags are composed from
/// 32-bit phase/sequence halves and can never collide with it.
pub(crate) const POISON_TAG: u64 = u64::MAX;

/// Panic payload a rank aborts with when a peer's poison marker lands in
/// its inbox: the peer died mid-protocol, so blocking for its data would
/// deadlock the cluster. `Cluster::run` treats these as collateral of
/// the root failure, not failures of their own.
#[derive(Clone, Copy, Debug)]
pub struct PeerDied {
    /// Rank of the peer that died.
    pub src: usize,
}

/// Deterministic transport fault injection, mirroring
/// `storage::durable::crash`: tests arm a kill (or a delay) at the n-th
/// transport boundary a chosen rank crosses, so a membership sweep can
/// kill a rank at *every* send/recv boundary — not just between epochs.
///
/// Arming is thread-local to the driver thread; `Cluster::run` captures
/// the armed spec (like the chunk/storage knobs) and installs it in
/// every rank thread, so concurrent tests in one process cannot
/// contaminate each other. Only the armed rank's own thread advances the
/// shared counter, so ordinals are deterministic. A fired kill unwinds
/// with [`RankKilled`] via `resume_unwind` (no panic-hook noise);
/// `Cluster::run` catches it and surfaces a structured
/// `metrics::RankFailed`.
pub mod fault {
    use std::cell::RefCell;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    /// Transport boundaries a fault can fire at.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum FaultPoint {
        /// Entry of `Ctx::send` (covers every chunk of `send_chunked`).
        Send,
        /// Entry of `Ctx::recv` (before blocking).
        Recv,
        /// Entry of `Ctx::send_service` (service-plane requests).
        ServiceSend,
    }

    impl FaultPoint {
        /// Stable name for reports and assertions.
        pub fn name(self) -> &'static str {
            match self {
                FaultPoint::Send => "send",
                FaultPoint::Recv => "recv",
                FaultPoint::ServiceSend => "service-send",
            }
        }
    }

    /// Panic payload [`step`] kills the armed rank with.
    #[derive(Clone, Copy, Debug)]
    pub struct RankKilled {
        /// The rank that was killed.
        pub rank: usize,
        /// The boundary the kill fired at.
        pub point: FaultPoint,
        /// 1-based ordinal of that boundary in the rank's execution.
        pub ordinal: u64,
    }

    /// One armed fault configuration (kill and/or delay), shared between
    /// the driver thread and the rank threads of the runs it launches.
    #[derive(Clone, Debug, Default)]
    pub struct FaultSpec {
        /// Rank whose transport boundaries are counted (and killed).
        kill_rank: Option<usize>,
        /// Fire the kill at this 1-based boundary; 0 = probe (count only).
        kill_step: u64,
        /// Boundary crossings by `kill_rank` so far.
        counter: Arc<AtomicU64>,
        /// Rank whose n-th send is delayed.
        delay_rank: Option<usize>,
        delay_step: u64,
        delay_secs: f64,
        delay_counter: Arc<AtomicU64>,
    }

    thread_local! {
        static ARMED: RefCell<Option<FaultSpec>> = const { RefCell::new(None) };
    }

    fn with_spec(f: impl FnOnce(&mut FaultSpec)) {
        ARMED.with(|a| {
            let mut a = a.borrow_mut();
            f(a.get_or_insert_with(FaultSpec::default));
        });
    }

    /// Kill `rank` at the `nth` (1-based) transport boundary it crosses.
    /// Resets the boundary counter.
    pub fn arm_kill(rank: usize, nth: u64) {
        assert!(nth >= 1, "kill ordinal is 1-based");
        with_spec(|s| {
            s.kill_rank = Some(rank);
            s.kill_step = nth;
            s.counter = Arc::new(AtomicU64::new(0));
        });
    }

    /// Count `rank`'s transport boundaries without firing — the sweep
    /// extent: a disarmed probe run's [`count`] is how many kill points
    /// the schedule has.
    pub fn probe(rank: usize) {
        with_spec(|s| {
            s.kill_rank = Some(rank);
            s.kill_step = 0;
            s.counter = Arc::new(AtomicU64::new(0));
        });
    }

    /// Add `secs` of simulated latency to the `nth` (1-based) send of
    /// `rank` — a message-delay point. Delays change simulated time,
    /// never values (the determinism contract's time/value split).
    pub fn arm_delay(rank: usize, nth: u64, secs: f64) {
        assert!(nth >= 1, "delay ordinal is 1-based");
        with_spec(|s| {
            s.delay_rank = Some(rank);
            s.delay_step = nth;
            s.delay_secs = secs;
            s.delay_counter = Arc::new(AtomicU64::new(0));
        });
    }

    /// Disarm everything on this thread.
    pub fn disarm() {
        ARMED.with(|a| *a.borrow_mut() = None);
    }

    /// Boundary crossings by the armed/probed rank in runs launched since
    /// the last `arm_kill`/`probe` on this thread.
    pub fn count() -> u64 {
        ARMED.with(|a| {
            a.borrow().as_ref().map_or(0, |s| s.counter.load(Ordering::Relaxed))
        })
    }

    /// Capture this thread's armed spec (`Cluster::run` calls this on the
    /// driver, like the chunk-rows capture).
    pub(crate) fn capture() -> Option<FaultSpec> {
        ARMED.with(|a| a.borrow().clone())
    }

    /// Install a captured spec in a rank thread.
    pub(crate) fn install(spec: Option<FaultSpec>) {
        ARMED.with(|a| *a.borrow_mut() = spec);
    }

    /// Called by `Ctx` at every transport boundary of `rank`. Counts the
    /// crossing when `rank` is the armed target and unwinds with
    /// [`RankKilled`] at the armed ordinal.
    pub(crate) fn step(rank: usize, point: FaultPoint) {
        let fire = ARMED.with(|a| {
            let a = a.borrow();
            let Some(s) = a.as_ref() else { return None };
            if s.kill_rank != Some(rank) {
                return None;
            }
            let n = s.counter.fetch_add(1, Ordering::Relaxed) + 1;
            (s.kill_step != 0 && n == s.kill_step).then_some(n)
        });
        if let Some(ordinal) = fire {
            std::panic::resume_unwind(Box::new(RankKilled { rank, point, ordinal }));
        }
    }

    /// Extra simulated seconds to add to this send of `rank` (0.0 unless
    /// an armed delay's ordinal matches).
    pub(crate) fn send_delay(rank: usize) -> f64 {
        ARMED.with(|a| {
            let a = a.borrow();
            let Some(s) = a.as_ref() else { return 0.0 };
            if s.delay_rank != Some(rank) {
                return 0.0;
            }
            let n = s.delay_counter.fetch_add(1, Ordering::Relaxed) + 1;
            if n == s.delay_step {
                s.delay_secs
            } else {
                0.0
            }
        })
    }

    /// True when `err` (from `Cluster::run`) is an injected transport
    /// kill — the membership sweep's "this failure was mine" check.
    pub fn is_injected(err: &anyhow::Error) -> bool {
        err.chain().any(|c| {
            matches!(
                c.downcast_ref::<super::super::metrics::RankFailed>(),
                Some(f) if f.point.is_some()
            )
        })
    }
}

/// Network parameters. Defaults mirror the paper's testbed (25 Gbps
/// Ethernet between EC2 instances; 100 µs is a typical same-AZ RTT/2 plus
/// stack overhead).
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Link bandwidth in gigabits per second.
    pub bandwidth_gbps: f64,
    /// Per-transfer latency in seconds (applied once per message — a
    /// chunked transfer therefore pays it once per chunk; see
    /// [`chunked_transfer_secs`](NetConfig::chunked_transfer_secs)).
    pub latency_secs: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 }
    }
}

impl NetConfig {
    /// Seconds to move `bytes` over one link, excluding queueing.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_secs + (bytes as f64 * 8.0) / (self.bandwidth_gbps * 1e9)
    }

    /// Seconds until the *last* chunk of a `bytes` payload split into `k`
    /// link transfers completes, excluding queueing and per-chunk envelope
    /// bytes: `k · latency + bytes / bandwidth`. Equals
    /// [`transfer_secs`](NetConfig::transfer_secs) at `k = 1`; the
    /// `(k − 1) · latency` surplus is the honest price of pipelining,
    /// which the overlap with compute must buy back
    /// (`primitives::costs::pipelined_step_secs`).
    pub fn chunked_transfer_secs(&self, bytes: u64, k: u64) -> f64 {
        self.latency_secs * k.max(1) as f64 + (bytes as f64 * 8.0) / (self.bandwidth_gbps * 1e9)
    }
}

/// Message tag for matching sends to receives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag(pub u64);

impl Tag {
    /// Compose a tag from a phase id and a sequence number (primitives use
    /// this to keep group communications distinct).
    pub fn of(phase: u32, seq: u32) -> Tag {
        Tag(((phase as u64) << 32) | seq as u64)
    }
}

/// Typed message payloads. Sizes are the *wire* sizes used for byte
/// accounting and transfer-time computation.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Raw bytes.
    Bytes(Vec<u8>),
    /// 32-bit ids (column indices, node ids).
    U32(Vec<u32>),
    /// Flat f32 data (edge values, attention scores).
    F32(Vec<f32>),
    /// A dense matrix (feature tiles).
    Matrix(Matrix),
    /// Empty control message.
    Empty,
}

impl Payload {
    /// Wire size in bytes: data plus a fixed 64-byte envelope (src, tag,
    /// shape, lengths). Every message pays the envelope, so a chunked
    /// transfer is honestly charged one envelope per chunk.
    pub fn nbytes(&self) -> u64 {
        const HEADER: u64 = 64; // envelope: src, tag, shape, lengths
        HEADER
            + match self {
                Payload::Bytes(b) => b.len() as u64,
                Payload::U32(v) => 4 * v.len() as u64,
                Payload::F32(v) => 4 * v.len() as u64,
                Payload::Matrix(m) => m.nbytes(),
                Payload::Empty => 0,
            }
    }

    /// Unwrap a [`Payload::Matrix`]; panics on any other variant.
    pub fn into_matrix(self) -> Matrix {
        match self {
            Payload::Matrix(m) => m,
            other => panic!("expected Matrix payload, got {:?}", other.kind()),
        }
    }

    /// Unwrap a [`Payload::U32`]; panics on any other variant.
    pub fn into_u32(self) -> Vec<u32> {
        match self {
            Payload::U32(v) => v,
            other => panic!("expected U32 payload, got {:?}", other.kind()),
        }
    }

    /// Unwrap a [`Payload::F32`]; panics on any other variant.
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            other => panic!("expected F32 payload, got {:?}", other.kind()),
        }
    }

    /// Variant name, for protocol-mismatch diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Bytes(_) => "Bytes",
            Payload::U32(_) => "U32",
            Payload::F32(_) => "F32",
            Payload::Matrix(_) => "Matrix",
            Payload::Empty => "Empty",
        }
    }
}

/// A message in flight.
pub struct Message {
    /// Sending machine's rank.
    pub src: usize,
    /// Raw tag bits ([`Tag`] phase/sequence composition).
    pub tag: u64,
    /// Simulated time at which the payload is fully received.
    pub ready_at: f64,
    /// The data being moved.
    pub payload: Payload,
}

/// Per-directed-link busy tracking shared by all machines.
pub struct LinkTable {
    world: usize,
    net: NetConfig,
    busy_until: Mutex<Vec<f64>>,
}

impl LinkTable {
    /// A table for `world` machines over pairwise `net`-modeled links.
    pub fn new(world: usize, net: NetConfig) -> Self {
        LinkTable { world, net, busy_until: Mutex::new(vec![0.0; world * world]) }
    }

    /// Schedule a transfer; returns its completion (ready) time.
    pub fn schedule(&self, src: usize, dst: usize, sender_now: f64, bytes: u64) -> f64 {
        if src == dst {
            // Local move: modeled as free (it is a pointer hand-off in a
            // real system too — same machine, no NIC).
            return sender_now;
        }
        let idx = src * self.world + dst;
        let mut busy = self.busy_until.lock().unwrap();
        let start = busy[idx].max(sender_now);
        let done = start + self.net.transfer_secs(bytes);
        busy[idx] = done;
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let net = NetConfig { bandwidth_gbps: 25.0, latency_secs: 100e-6 };
        let t = net.transfer_secs(25_000_000_000 / 8); // 1 second of bytes
        assert!((t - 1.0001).abs() < 1e-9);
    }

    #[test]
    fn link_serializes_transfers() {
        let net = NetConfig { bandwidth_gbps: 1.0, latency_secs: 0.0 };
        let links = LinkTable::new(2, net);
        let b = 1_000_000_000 / 8; // 1 second each
        let t1 = links.schedule(0, 1, 0.0, b);
        let t2 = links.schedule(0, 1, 0.0, b);
        assert!((t1 - 1.0).abs() < 1e-9);
        assert!((t2 - 2.0).abs() < 1e-9, "second transfer must queue");
        // opposite direction is an independent link
        let t3 = links.schedule(1, 0, 0.0, b);
        assert!((t3 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn local_transfer_is_free() {
        let links = LinkTable::new(2, NetConfig::default());
        assert_eq!(links.schedule(0, 0, 5.0, 1 << 30), 5.0);
    }

    #[test]
    fn payload_sizes() {
        assert_eq!(Payload::U32(vec![0; 10]).nbytes(), 64 + 40);
        assert_eq!(Payload::F32(vec![0.0; 10]).nbytes(), 64 + 40);
        assert_eq!(Payload::Empty.nbytes(), 64);
        let m = Matrix::zeros(3, 4);
        assert_eq!(Payload::Matrix(m).nbytes(), 64 + 48);
    }

    #[test]
    fn tag_composition() {
        let t = Tag::of(3, 7);
        assert_eq!(t.0, (3u64 << 32) | 7);
        assert_ne!(Tag::of(3, 7), Tag::of(7, 3));
    }

    #[test]
    fn chunk_bounds_shapes() {
        assert_eq!(chunk_bounds_for(10, 0), vec![0, 10]);
        assert_eq!(chunk_bounds_for(10, 16), vec![0, 10]);
        assert_eq!(chunk_bounds_for(10, 10), vec![0, 10]);
        assert_eq!(chunk_bounds_for(10, 4), vec![0, 4, 8, 10]);
        assert_eq!(chunk_bounds_for(10, 1).len(), 11);
        assert_eq!(chunk_bounds_for(0, 4), vec![0, 0]);
    }

    #[test]
    fn chunk_rows_resolution_order() {
        with_chunk_rows(7, || {
            assert_eq!(chunk_rows(), 7);
            with_chunk_rows(0, || assert_eq!(chunk_rows(), 0));
            assert_eq!(chunk_rows(), 7);
        });
        // outside any scope: global/env/default, all >= 0 by construction
        let _ = chunk_rows();
    }

    #[test]
    fn chunk_rows_guard_pins_and_restores() {
        with_chunk_rows(11, || {
            {
                let _g = ChunkRowsGuard::pin(3);
                assert_eq!(chunk_rows(), 3);
                let inner = ChunkRowsGuard::pin(0);
                assert_eq!(chunk_rows(), 0);
                drop(inner);
                assert_eq!(chunk_rows(), 3);
            }
            assert_eq!(chunk_rows(), 11);
        });
    }

    #[test]
    fn per_chunk_completion_times_sum_to_monolithic_plus_latency() {
        // Splitting a payload into k link transfers must cost exactly the
        // monolithic transfer time plus (k - 1) extra latency terms — the
        // LogP model keeps byte time linear, so only the fixed per-message
        // cost multiplies.
        let net = NetConfig { bandwidth_gbps: 10.0, latency_secs: 50e-6 };
        let links = LinkTable::new(2, net);
        let payload_bytes: u64 = 1 << 20;
        let bounds = chunk_bounds_for(1024, 128); // 8 chunks
        let k = (bounds.len() - 1) as u64;
        let per_chunk = payload_bytes / k;
        let mut last = 0.0;
        let mut prev = 0.0;
        for _ in 0..k {
            last = links.schedule(0, 1, 0.0, per_chunk);
            assert!(last > prev, "chunk stamps must be strictly increasing");
            prev = last;
        }
        let mono = net.transfer_secs(payload_bytes);
        let expect = mono + (k - 1) as f64 * net.latency_secs;
        assert!((last - expect).abs() < 1e-12, "last={} expect={}", last, expect);
        assert!((net.chunked_transfer_secs(payload_bytes, k) - expect).abs() < 1e-12);
        assert!((net.chunked_transfer_secs(payload_bytes, 1) - mono).abs() < 1e-15);
    }
}
