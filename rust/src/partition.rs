//! Topology + feature collaborative partitioning (paper §3.3, Fig. 6).
//!
//! The graph is 1-D partitioned into `P` contiguous destination-row ranges;
//! the feature tensor of each graph partition is further split column-wise
//! across `M` machines. Machine `(p, m)` (rank `p*M + m`) holds:
//!
//! - a full copy of partition `p`'s edges (rows `node_bounds[p] ..
//!   node_bounds[p+1]`, global columns), and
//! - feature columns `feat_bounds[m] .. feat_bounds[m+1]` of those rows.
//!
//! This is deliberately *lightweight* (pure index arithmetic — the paper's
//! Observation #1: advanced partitioners cost more than they save in a
//! single forward pass) and is what bounds both the memory and the
//! communication of the distributed primitives (§3.4, Tables 1–3).

use crate::graph::NodeId;
use crate::util::even_ranges;

/// The collaborative partition plan shared by every machine.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionPlan {
    pub n_nodes: usize,
    pub feature_dim: usize,
    /// Number of graph (row) partitions.
    pub p: usize,
    /// Number of feature (column) partitions per graph partition.
    pub m: usize,
    /// `p + 1` node range boundaries.
    pub node_bounds: Vec<usize>,
    /// `m + 1` feature column boundaries.
    pub feat_bounds: Vec<usize>,
}

impl PartitionPlan {
    pub fn new(n_nodes: usize, feature_dim: usize, p: usize, m: usize) -> Self {
        assert!(p >= 1 && m >= 1);
        assert!(
            feature_dim >= m,
            "feature dim {} must be >= feature parts {}",
            feature_dim,
            m
        );
        PartitionPlan {
            n_nodes,
            feature_dim,
            p,
            m,
            node_bounds: even_ranges(n_nodes, p),
            feat_bounds: even_ranges(feature_dim, m),
        }
    }

    /// Total machines in the plan.
    pub fn world(&self) -> usize {
        self.p * self.m
    }

    /// Rank of machine at (graph part, feature part).
    #[inline]
    pub fn rank_of(&self, p_idx: usize, m_idx: usize) -> usize {
        debug_assert!(p_idx < self.p && m_idx < self.m);
        p_idx * self.m + m_idx
    }

    /// (graph part, feature part) of a rank.
    #[inline]
    pub fn coords_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.world());
        (rank / self.m, rank % self.m)
    }

    /// Node (row) range of graph partition `p_idx`.
    #[inline]
    pub fn node_range(&self, p_idx: usize) -> (usize, usize) {
        (self.node_bounds[p_idx], self.node_bounds[p_idx + 1])
    }

    /// Number of rows in graph partition `p_idx`.
    #[inline]
    pub fn rows_of(&self, p_idx: usize) -> usize {
        self.node_bounds[p_idx + 1] - self.node_bounds[p_idx]
    }

    /// Feature column range of feature partition `m_idx`.
    #[inline]
    pub fn feat_range(&self, m_idx: usize) -> (usize, usize) {
        (self.feat_bounds[m_idx], self.feat_bounds[m_idx + 1])
    }

    /// Width of feature partition `m_idx`.
    #[inline]
    pub fn feat_width(&self, m_idx: usize) -> usize {
        self.feat_bounds[m_idx + 1] - self.feat_bounds[m_idx]
    }

    /// Graph partition owning global node `v`.
    #[inline]
    pub fn node_owner(&self, v: NodeId) -> usize {
        crate::graph::builder::owner_of(v as usize, &self.node_bounds)
    }

    /// Ranks sharing graph partition `p_idx` (Fig. 6: "machines hosting the
    /// same partition"), in feature-part order.
    pub fn row_group(&self, p_idx: usize) -> Vec<usize> {
        (0..self.m).map(|m_idx| self.rank_of(p_idx, m_idx)).collect()
    }

    /// Ranks holding feature part `m_idx` across all graph partitions (the
    /// machines a feature-exchange SPMM talks to), in graph-part order.
    pub fn col_group(&self, m_idx: usize) -> Vec<usize> {
        (0..self.p).map(|p_idx| self.rank_of(p_idx, m_idx)).collect()
    }

    /// The serving-tier layout derived from this inference plan: the same
    /// `P` contiguous row ranges (identical `node_bounds`, so the machine
    /// that computed a node's embedding owns serving it), a single feature
    /// part of the embedding width `out_dim` (the GNN output width usually
    /// differs from the input feature width). Used by
    /// `serve::ShardedTable::from_inference_plan`.
    pub fn serving(&self, out_dim: usize) -> PartitionPlan {
        PartitionPlan::new(self.n_nodes, out_dim.max(1), self.p, 1)
    }

    /// A plan with the same machines reinterpreted with a different (p, m)
    /// factorization — Fig. 18 sweeps these configurations.
    pub fn refactor(&self, p: usize, m: usize) -> PartitionPlan {
        assert_eq!(p * m, self.world(), "must keep machine count");
        PartitionPlan::new(self.n_nodes, self.feature_dim, p, m)
    }

    /// Re-shard for an **elastic** world of `p × m` machines — unlike
    /// [`refactor`](PartitionPlan::refactor) the world may grow or shrink
    /// (a membership transition's target layout). Node set and feature
    /// width are preserved; the new layout is validated instead of
    /// asserted so a bad target (zero parts, more feature parts than
    /// columns) is a recoverable error for the membership driver.
    pub fn refactor_world(&self, p: usize, m: usize) -> Result<PartitionPlan, String> {
        if p < 1 || m < 1 {
            return Err(format!("elastic layout needs p,m >= 1 (got {}x{})", p, m));
        }
        if self.feature_dim < m {
            return Err(format!(
                "feature dim {} cannot split into {} parts",
                self.feature_dim, m
            ));
        }
        let plan = PartitionPlan::new(self.n_nodes, self.feature_dim, p, m);
        plan.validate()?;
        Ok(plan)
    }

    /// Row segments of the merged band structure of `self` and `new`
    /// (same node set): every maximal row interval on which both plans'
    /// ownership is constant, with the owning graph part under each. The
    /// union of the segments covers `[0, n)` exactly once; segments whose
    /// owner *part* is unchanged are what an incremental re-shard keeps
    /// in place (modulo the part→rank mapping, which the membership
    /// layer applies).
    pub fn band_segments(&self, new: &PartitionPlan) -> Vec<BandSegment> {
        assert_eq!(self.n_nodes, new.n_nodes, "band diff needs one node set");
        let mut cuts: Vec<usize> = self
            .node_bounds
            .iter()
            .chain(new.node_bounds.iter())
            .copied()
            .collect();
        cuts.sort_unstable();
        cuts.dedup();
        cuts.windows(2)
            .filter(|w| w[1] > w[0])
            .map(|w| BandSegment {
                lo: w[0],
                hi: w[1],
                old_part: self.node_owner(w[0] as NodeId),
                new_part: new.node_owner(w[0] as NodeId),
            })
            .collect()
    }

    /// The segments of [`band_segments`](PartitionPlan::band_segments)
    /// whose owning part changes — the minimal move set of an incremental
    /// re-shard between two same-world plans.
    pub fn band_diff(&self, new: &PartitionPlan) -> Vec<BandSegment> {
        self.band_segments(new)
            .into_iter()
            .filter(|s| s.old_part != s.new_part)
            .collect()
    }

    /// Structural invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.node_bounds.len() != self.p + 1 || self.feat_bounds.len() != self.m + 1 {
            return Err("bounds arity".into());
        }
        if self.node_bounds[0] != 0 || *self.node_bounds.last().unwrap() != self.n_nodes {
            return Err("node bounds must cover [0, n)".into());
        }
        if self.feat_bounds[0] != 0 || *self.feat_bounds.last().unwrap() != self.feature_dim {
            return Err("feature bounds must cover [0, D)".into());
        }
        // every rank appears exactly once across row groups
        let mut seen = vec![false; self.world()];
        for p_idx in 0..self.p {
            for r in self.row_group(p_idx) {
                if seen[r] {
                    return Err(format!("rank {} in two row groups", r));
                }
                seen[r] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("rank missing from row groups".into());
        }
        Ok(())
    }
}

/// One row interval of the merged band structure of two plans (see
/// [`PartitionPlan::band_segments`]): rows `[lo, hi)` belong to graph
/// part `old_part` under the old plan and `new_part` under the new one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandSegment {
    pub lo: usize,
    pub hi: usize,
    pub old_part: usize,
    pub new_part: usize,
}

impl BandSegment {
    /// Rows in the segment.
    pub fn rows(&self) -> usize {
        self.hi - self.lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    #[test]
    fn figure6_layout() {
        // The paper's toy example: 8 nodes, P=2, M=2, 4 machines.
        let plan = PartitionPlan::new(8, 4, 2, 2);
        assert_eq!(plan.world(), 4);
        assert_eq!(plan.node_range(0), (0, 4));
        assert_eq!(plan.node_range(1), (4, 8));
        assert_eq!(plan.feat_range(0), (0, 2));
        assert_eq!(plan.feat_range(1), (2, 4));
        // machines 0,1 host partition 0; machines 2,3 host partition 1
        assert_eq!(plan.row_group(0), vec![0, 1]);
        assert_eq!(plan.row_group(1), vec![2, 3]);
        assert_eq!(plan.col_group(0), vec![0, 2]);
        assert_eq!(plan.col_group(1), vec![1, 3]);
        assert_eq!(plan.coords_of(3), (1, 1));
        assert_eq!(plan.node_owner(5), 1);
        plan.validate().unwrap();
    }

    #[test]
    fn refactor_preserves_world() {
        let plan = PartitionPlan::new(100, 64, 4, 2);
        let r = plan.refactor(2, 4);
        assert_eq!(r.world(), plan.world());
        assert_eq!(r.p, 2);
        r.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "must keep machine count")]
    fn refactor_rejects_different_world() {
        PartitionPlan::new(100, 64, 4, 2).refactor(3, 2);
    }

    #[test]
    fn serving_plan_keeps_row_ownership() {
        let plan = PartitionPlan::new(100, 64, 4, 2);
        let s = plan.serving(16);
        assert_eq!(s.p, 4);
        assert_eq!(s.m, 1);
        assert_eq!(s.feature_dim, 16);
        assert_eq!(s.node_bounds, plan.node_bounds);
        s.validate().unwrap();
        // zero-width embeddings still produce a valid layout
        assert_eq!(plan.serving(0).feature_dim, 1);
    }

    #[test]
    fn refactor_world_handles_p_or_m_of_one() {
        let plan = PartitionPlan::new(100, 64, 4, 2);
        let p1 = plan.refactor_world(1, 1).unwrap();
        assert_eq!(p1.world(), 1);
        assert_eq!(p1.node_range(0), (0, 100));
        p1.validate().unwrap();
        let m1 = plan.refactor_world(5, 1).unwrap();
        assert_eq!(m1.world(), 5);
        m1.validate().unwrap();
        let tall = plan.refactor_world(1, 8).unwrap();
        assert_eq!((tall.p, tall.m), (1, 8));
        tall.validate().unwrap();
    }

    #[test]
    fn refactor_world_rejects_degenerate_shrink() {
        let plan = PartitionPlan::new(100, 4, 4, 2);
        // shrinking to zero ranks, or below the feature replica count
        // (more column parts than columns), is a recoverable error
        assert!(plan.refactor_world(0, 1).is_err());
        assert!(plan.refactor_world(1, 0).is_err());
        assert!(plan.refactor_world(1, 5).is_err(), "4 columns cannot split 5 ways");
        // growth past the old world is fine — that's the elastic point
        assert_eq!(plan.refactor_world(16, 1).unwrap().world(), 16);
    }

    #[test]
    fn refactor_world_keeps_uneven_row_bands_covering() {
        // 10 rows over 3 then 4 parts: bands are uneven in both layouts;
        // the segments must still tile [0, n) exactly once.
        let a = PartitionPlan::new(10, 8, 3, 1);
        let b = a.refactor_world(4, 1).unwrap();
        assert_eq!(a.node_bounds, vec![0, 4, 7, 10], "ceil-heavy front bands");
        let segs = a.band_segments(&b);
        assert_eq!(segs.first().unwrap().lo, 0);
        assert_eq!(segs.last().unwrap().hi, 10);
        for w in segs.windows(2) {
            assert_eq!(w[0].hi, w[1].lo, "segments must tile without gaps");
        }
        // each segment's owner matches both plans row by row
        for s in &segs {
            for v in s.lo..s.hi {
                assert_eq!(a.node_owner(v as NodeId), s.old_part);
                assert_eq!(b.node_owner(v as NodeId), s.new_part);
            }
        }
        // and the diff is a strict subset: unchanged-part segments stay home
        let moved: usize = a.band_diff(&b).iter().map(|s| s.rows()).sum();
        assert!(moved < 10, "incremental diff must not move every row");
        assert!(moved > 0, "3 -> 4 parts must move something");
    }

    #[test]
    fn refactor_then_refactor_round_trip_preserves_node_owner() {
        let plan = PartitionPlan::new(137, 32, 4, 2);
        let grown = plan.refactor_world(6, 2).unwrap();
        let back = grown.refactor_world(4, 2).unwrap();
        assert_eq!(back, plan, "round trip reproduces the layout exactly");
        for v in 0..137usize {
            assert_eq!(back.node_owner(v as NodeId), plan.node_owner(v as NodeId));
        }
        // and a same-world refactor round trip through the legacy path
        let re = plan.refactor(8, 1).refactor(4, 2);
        assert_eq!(re, plan);
    }

    #[test]
    fn band_diff_empty_for_identical_plans() {
        let plan = PartitionPlan::new(64, 16, 4, 1);
        let same = plan.refactor_world(4, 1).unwrap();
        assert!(plan.band_diff(&same).is_empty());
        let segs = plan.band_segments(&same);
        assert_eq!(segs.len(), 4, "one segment per unchanged band");
    }

    #[test]
    fn plan_invariants_property() {
        run(Config::default().cases(32), |rng| {
            let p = rng.range(1, 6);
            let m = rng.range(1, 6);
            let n = rng.range(p.max(2), 500);
            let d = rng.range(m.max(4), 300);
            let plan = PartitionPlan::new(n, d, p, m);
            plan.validate()?;
            // node_owner is consistent with node_range
            for _ in 0..20 {
                let v = rng.next_below(n) as NodeId;
                let owner = plan.node_owner(v);
                let (lo, hi) = plan.node_range(owner);
                if !(lo..hi).contains(&(v as usize)) {
                    return Err(format!("node {} not in range of owner {}", v, owner));
                }
            }
            // coords round-trip
            for r in 0..plan.world() {
                let (pi, mi) = plan.coords_of(r);
                if plan.rank_of(pi, mi) != r {
                    return Err("coords round trip".into());
                }
            }
            Ok(())
        });
    }
}
